"""Level 2 — fused multi-operator problems (20 of the paper's subset).

Each problem is a GEMM/BMM plus an elementwise/normalization tail; the whole
point of this level is that a good agent folds the tail into the kernel
epilogue (one HBM round-trip) while the baseline pays a pass per op.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .base import Problem, seg

_DT = "  .with_dtype(input=bf16, acc=fp32, output=bf16)"
M, N, K = 4096, 4096, 4096
_NUMEL = M * N


def _g(rng, *shape):
    return rng.standard_normal(shape).astype(np.float32)


def _fusion_problem(pid, name, rationale, tail, reference, make_inputs,
                    dsl, m=M, n=N, k=K, extra_segments=()):
    """tail: list of (seg_name, epilogue_op, flops_per_elem, fusable)."""
    segs = [seg("gemm", "matmul", m=m, n=n, k=k)]
    for tname, ep_op, fpe, fusable in tail:
        segs.append(seg(tname, "eltwise", numel=m * n, flops_per_elem=fpe,
                        fusable=fusable, epilogue_op=ep_op))
    segs.extend(extra_segments)
    return Problem(pid=pid, level=2, name=name, rationale=rationale,
                   segments=segs, make_inputs=make_inputs,
                   reference=reference, dsl_template=dsl)


def build() -> list:
    P = []
    rm, rn, rk = 96, 80, 64

    def mk_ab(rng):
        return (_g(rng, rm, rk), _g(rng, rk, rn))

    def mk_ab_bias(rng):
        return (_g(rng, rm, rk), _g(rng, rk, rn), _g(rng, rn))

    gemm_tpl = ("gemm()\n" + _DT +
                "\n  .with_tile(m=256, n=256, k=512).with_stages(2)")

    # L2/9: fused matmul + elementwise
    P.append(_fusion_problem(
        "L2/9", "gemm_gelu", "Proxy for epilogue and MLP fusions.",
        [("act", "gelu", 8, True)],
        lambda a, b: jax.nn.gelu(a @ b, approximate=True), mk_ab,
        {"gemm": gemm_tpl + " >> gelu()"}))

    # L2/28: BMM fusion representative of MHA dataflow
    bh, s, d = 64, 1024, 128
    P.append(Problem(
        pid="L2/28", name="bmm_softmax_bmm",
        rationale="BMM fusion representative of multi-head attention.",
        level=2,
        segments=[seg("scores", "matmul", m=s, n=s, k=d, batch=bh),
                  seg("softmax", "norm", rows=bh * s, d=s, norm="softmax"),
                  seg("pv", "matmul", m=s, n=d, k=s, batch=bh)],
        make_inputs=lambda rng: (_g(rng, 2, 64, 32), _g(rng, 2, 64, 32),
                                 _g(rng, 2, 64, 32)),
        reference=lambda q, k, v: jnp.einsum(
            "bqk,bkd->bqd",
            jax.nn.softmax(jnp.einsum("bqd,bkd->bqk", q, k)
                           / (q.shape[-1] ** 0.5), -1), v),
        dsl_template={"scores": "attention(causal=false)\n" + _DT +
                      "\n  .with_block(q=128, kv=256)"}))

    # L2/29: fused linear + activation
    P.append(_fusion_problem(
        "L2/29", "linear_silu", "MLP fusion pattern.",
        [("act", "silu", 5, True)],
        lambda a, b: (lambda x: x * jax.nn.sigmoid(x))(a @ b), mk_ab,
        {"gemm": gemm_tpl + " >> silu()"}, m=8192, n=8192, k=2048))

    # L2/37: fused linear + normalization
    P.append(Problem(
        pid="L2/37", name="linear_rmsnorm",
        rationale="Proxy for norm-adjacent fusions.", level=2,
        segments=[seg("gemm", "matmul", m=M, n=N, k=K),
                  seg("norm", "norm", rows=M, d=N, norm="rmsnorm")],
        make_inputs=lambda rng: (_g(rng, rm, rk), _g(rng, rk, rn),
                                 _g(rng, rn)),
        reference=lambda a, b, g: (lambda x: x * jax.lax.rsqrt(
            jnp.mean(jnp.square(x), -1, keepdims=True) + 1e-6) * g)(a @ b),
        dsl_template={"gemm": gemm_tpl,
                      "norm": "rmsnorm(eps=0.000001)"
                      ".with_dtype(input=bf16, acc=fp32, output=bf16)"}))

    # L2/40: fused linear + residual add
    P.append(_fusion_problem(
        "L2/40", "linear_residual", "Transformer block core pattern.",
        [("res", "residual_add", 1, True)],
        lambda a, b, r: a @ b + r,
        lambda rng: (_g(rng, rm, rk), _g(rng, rk, rn), _g(rng, rm, rn)),
        {"gemm": gemm_tpl + " >> residual_add()"}))

    # L2/41: GEMM + multi-activation fusion
    P.append(_fusion_problem(
        "L2/41", "gemm_multi_act", "MLP epilogue diversity.",
        [("act1", "gelu", 8, True), ("act2", "tanh", 4, True)],
        lambda a, b: jnp.tanh(jax.nn.gelu(a @ b, approximate=True)), mk_ab,
        {"gemm": gemm_tpl + " >> gelu() >> tanh()"}))

    # L2/53: GEMM + activation (+ scaling)
    P.append(_fusion_problem(
        "L2/53", "gemm_relu_scale", "Activation/scaling variants.",
        [("act", "relu", 1, True), ("sc", "scale", 1, True)],
        lambda a, b: jnp.maximum(a @ b, 0) * 0.5, mk_ab,
        {"gemm": gemm_tpl + " >> relu() >> scale(value=0.5)"}))

    # L2/56: matmul + gating + reduction
    P.append(Problem(
        pid="L2/56", name="gemm_gate_reduce",
        rationale="Proxy for gated aggregation patterns.", level=2,
        segments=[seg("gemm", "matmul", m=M, n=N, k=K),
                  seg("gate", "eltwise", numel=_NUMEL, flops_per_elem=4,
                      fusable=True, epilogue_op="sigmoid"),
                  seg("reduce", "reduce", numel=_NUMEL, axis_len=N)],
        make_inputs=mk_ab,
        reference=lambda a, b: jnp.sum(jax.nn.sigmoid(a @ b), axis=-1),
        dsl_template={"gemm": gemm_tpl + " >> sigmoid()",
                      "reduce": "reduce(op=sum, axis=-1)"
                      ".with_dtype(input=bf16, acc=fp32, output=fp32)"}))

    # L2/59: matmul + swish + scaling
    P.append(_fusion_problem(
        "L2/59", "gemm_swish_scale", "Common MLP fusion.",
        [("act", "silu", 5, True), ("sc", "scale", 1, True)],
        lambda a, b: (lambda x: x * jax.nn.sigmoid(x))(a @ b) * 2.0, mk_ab,
        {"gemm": gemm_tpl + " >> silu() >> scale(value=2.0)"}))

    # L2/62: matmul + normalization + activation
    P.append(Problem(
        pid="L2/62", name="gemm_norm_act",
        rationale="Fused post-linear processing.", level=2,
        segments=[seg("gemm", "matmul", m=M, n=N, k=K),
                  seg("norm", "norm", rows=M, d=N, norm="layernorm"),
                  seg("act", "eltwise", numel=_NUMEL, flops_per_elem=8,
                      fusable=False, epilogue_op="gelu")],
        make_inputs=lambda rng: (_g(rng, rm, rk), _g(rng, rk, rn),
                                 _g(rng, rn), _g(rng, rn)),
        reference=lambda a, b, g, be: jax.nn.gelu(
            (lambda x: (x - jnp.mean(x, -1, keepdims=True))
             * jax.lax.rsqrt(jnp.var(x, -1, keepdims=True) + 1e-5)
             * g + be)(a @ b), approximate=True),
        dsl_template={"gemm": gemm_tpl,
                      "norm": "layernorm(eps=0.00001)"
                      ".with_dtype(input=bf16, acc=fp32, output=bf16)"
                      " >> gelu()"}))

    # L2/63: GEMM + ReLU + divide
    P.append(_fusion_problem(
        "L2/63", "gemm_relu_div", "Activation + scaling fusion.",
        [("act", "relu", 1, True), ("div", "scale", 1, True)],
        lambda a, b: jnp.maximum(a @ b, 0) / 8.0, mk_ab,
        {"gemm": gemm_tpl + " >> relu() >> scale(value=0.125)"}))

    # L2/66: attention-like fusion with dropout (training pattern)
    P.append(Problem(
        pid="L2/66", name="attention_dropout",
        rationale="Training attention pattern with dropout.", level=2,
        segments=[seg("scores", "matmul", m=1024, n=1024, k=128, batch=64),
                  seg("softmax", "norm", rows=64 * 1024, d=1024,
                      norm="softmax"),
                  seg("drop", "eltwise", numel=64 * 1024 * 1024,
                      flops_per_elem=2, fusable=True, epilogue_op="scale"),
                  seg("pv", "matmul", m=1024, n=128, k=1024, batch=64)],
        make_inputs=lambda rng: (_g(rng, 2, 64, 32), _g(rng, 2, 64, 32),
                                 _g(rng, 2, 64, 32)),
        # deterministic "inference-mode" dropout: scale by keep prob
        reference=lambda q, k, v: jnp.einsum(
            "bqk,bkd->bqd",
            jax.nn.softmax(jnp.einsum("bqd,bkd->bqk", q, k)
                           / (q.shape[-1] ** 0.5), -1) * 0.9, v),
        dsl_template={"scores": "attention(causal=false)\n" + _DT +
                      "\n  .with_block(q=128, kv=256)"}))

    # L2/70: GEMM + sigmoid gate + residual add (SwiGLU-like)
    P.append(_fusion_problem(
        "L2/70", "gemm_gate_residual", "SwiGLU-like gating proxy.",
        [("gate", "custom", 5, True), ("res", "residual_add", 1, True)],
        lambda a, b, r: (lambda x: x * jax.nn.sigmoid(x))(a @ b) + r,
        lambda rng: (_g(rng, rm, rk), _g(rng, rk, rn), _g(rng, rm, rn)),
        {"gemm": gemm_tpl + " >> custom('x * sigmoid(x)') >> residual_add()"}))

    # L2/76: GEMM + bias add + ReLU (classic epilogue fusion)
    P.append(_fusion_problem(
        "L2/76", "gemm_bias_relu", "Classic epilogue fusion.",
        [("bias", "bias", 1, True), ("act", "relu", 1, True)],
        lambda a, b, bi: jnp.maximum(a @ b + bi[None, :], 0), mk_ab_bias,
        {"gemm": gemm_tpl + " >> bias() >> relu()"}))

    # L2/81: complex epilogue fusion with Swish
    P.append(_fusion_problem(
        "L2/81", "gemm_bias_swish_clamp", "Stress fused elementwise.",
        [("bias", "bias", 1, True), ("act", "silu", 5, True),
         ("cl", "clamp", 2, True)],
        lambda a, b, bi: jnp.clip(
            (lambda x: x * jax.nn.sigmoid(x))(a @ b + bi[None, :]),
            -1.0, 1.0),
        mk_ab_bias,
        {"gemm": gemm_tpl +
         " >> bias() >> silu() >> clamp(min=-1.0, max=1.0)"}))

    # L2/86: matmul + divide + GELU
    P.append(_fusion_problem(
        "L2/86", "gemm_div_gelu", "MLP fusion with scaling.",
        [("div", "scale", 1, True), ("act", "gelu", 8, True)],
        lambda a, b: jax.nn.gelu((a @ b) * 0.25, approximate=True), mk_ab,
        {"gemm": gemm_tpl + " >> scale(value=0.25) >> gelu()"}))

    # L2/88: SwiGLU-like gated fusion (two GEMMs + gate + down proj)
    dff = 14336
    P.append(Problem(
        pid="L2/88", name="swiglu_mlp",
        rationale="Common LLM MLP pattern proxy.", level=2,
        segments=[seg("up", "matmul", m=M, n=dff, k=K),
                  seg("gatep", "matmul", m=M, n=dff, k=K),
                  seg("gate", "eltwise", numel=M * dff, flops_per_elem=5,
                      fusable=True, epilogue_op="custom"),
                  seg("down", "matmul", m=M, n=K, k=dff)],
        make_inputs=lambda rng: (_g(rng, rm, rk), _g(rng, rk, rn),
                                 _g(rng, rk, rn), _g(rng, rn, rk)),
        reference=lambda x, wu, wg, wd:
            ((x @ wu) * (lambda z: z * jax.nn.sigmoid(z))(x @ wg)) @ wd,
        dsl_template={
            "up": gemm_tpl,
            "gatep": gemm_tpl +
            " >> custom('(x * sigmoid(x)) * u', inputs={'u': 'full'})",
            "down": gemm_tpl}))

    # L2/94: expert MLP proxy: grouped GEMM + bias/activation + norm
    experts = 8
    P.append(Problem(
        pid="L2/94", name="expert_mlp",
        rationale="Expert MLP: grouped GEMM + bias/act + normalization.",
        level=2,
        segments=[seg("egemm", "matmul", m=M // experts, n=dff, k=K,
                      batch=experts),
                  seg("bias", "eltwise", numel=M * dff, flops_per_elem=1,
                      fusable=True, epilogue_op="bias"),
                  seg("act", "eltwise", numel=M * dff, flops_per_elem=8,
                      fusable=True, epilogue_op="gelu"),
                  seg("norm", "norm", rows=M, d=dff, norm="rmsnorm")],
        make_inputs=lambda rng: (_g(rng, 4, 64, 32), _g(rng, 4, 32, 48),
                                 _g(rng, 4, 48), _g(rng, 48)),
        reference=lambda x, w, bi, g: (lambda y: y * jax.lax.rsqrt(
            jnp.mean(jnp.square(y), -1, keepdims=True) + 1e-6) * g)(
                jax.nn.gelu(jnp.einsum("gmk,gkn->gmn", x, w)
                            + bi[:, None, :], approximate=True)),
        dsl_template={
            "egemm": f"grouped_gemm(expert_count={experts})\n" + _DT +
            "\n  .with_tile(m=128, n=128, k=256) >> bias() >> gelu()",
            "norm": "rmsnorm(eps=0.000001)"
            ".with_dtype(input=bf16, acc=fp32, output=bf16)"}))

    # L2/97: matmul + bias + norm + swish
    P.append(Problem(
        pid="L2/97", name="gemm_bias_norm_swish",
        rationale="Fused post-linear processing.", level=2,
        segments=[seg("gemm", "matmul", m=M, n=N, k=K),
                  seg("bias", "eltwise", numel=_NUMEL, flops_per_elem=1,
                      fusable=True, epilogue_op="bias"),
                  seg("norm", "norm", rows=M, d=N, norm="layernorm"),
                  seg("act", "eltwise", numel=_NUMEL, flops_per_elem=5,
                      fusable=False, epilogue_op="silu")],
        make_inputs=lambda rng: (_g(rng, rm, rk), _g(rng, rk, rn),
                                 _g(rng, rn), _g(rng, rn), _g(rng, rn)),
        reference=lambda a, b, bi, g, be: (lambda y: y * jax.nn.sigmoid(y))(
            (lambda x: (x - jnp.mean(x, -1, keepdims=True))
             * jax.lax.rsqrt(jnp.var(x, -1, keepdims=True) + 1e-5)
             * g + be)(a @ b + bi[None, :])),
        dsl_template={"gemm": gemm_tpl + " >> bias()",
                      "norm": "layernorm(eps=0.00001)"
                      ".with_dtype(input=bf16, acc=fp32, output=bf16)"
                      " >> silu()"}))

    # L2/99: attention-like fusion (matmul + GELU + softmax)
    P.append(Problem(
        pid="L2/99", name="gemm_gelu_softmax",
        rationale="Attention-like fusion.", level=2,
        segments=[seg("gemm", "matmul", m=M, n=N, k=K),
                  seg("act", "eltwise", numel=_NUMEL, flops_per_elem=8,
                      fusable=True, epilogue_op="gelu"),
                  seg("softmax", "norm", rows=M, d=N, norm="softmax")],
        make_inputs=mk_ab,
        reference=lambda a, b: jax.nn.softmax(
            jax.nn.gelu(a @ b, approximate=True), -1),
        dsl_template={"gemm": gemm_tpl + " >> gelu()",
                      "softmax": "softmax(axis=-1)"
                      ".with_dtype(input=bf16, acc=fp32, output=bf16)"}))
    return P
