"""Gap-aware ROI triage (paper Sec. 4.2, Triage phase).

    ROI(h) = S_hat(h)^(1 + max(0, log10(g/5))) / (R_impl(h) * R_perf(h))

The gap exponent amplifies ambition when far from SOL and encourages
incremental gains when close to it.
"""

from __future__ import annotations

import math
from typing import List, Sequence

from .policies import Hypothesis


def roi(h: Hypothesis, gap: float) -> float:
    s = max(h.est_speedup, 1e-6)
    exponent = 1.0 + max(0.0, math.log10(max(gap, 1e-9) / 5.0))
    return (s ** exponent) / (h.risk_impl * h.risk_perf)


def triage(hypotheses: Sequence[Hypothesis], gap: float,
           top_n: int) -> List[Hypothesis]:
    return sorted(hypotheses, key=lambda h: roi(h, gap), reverse=True)[:top_n]
