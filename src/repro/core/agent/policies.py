"""Proposal policies — deterministic analogues of the paper's agent classes.

The paper's searcher is an LLM; offline we make the proposal distribution
pluggable.  Three policies reproduce the paper's three agent classes:

  RawPolicy        "MI w/o muCUTLASS": emits low-level code whose validity is
                   only discovered by the toolchain — invalid configurations
                   burn a full compile/run/profile *attempt*.
  DSLPolicy        "MI + muCUTLASS": samples grammar-valid muPallas programs;
                   static validation rejects bad configs *before* an attempt
                   is consumed (re-roll costs tokens only).
  SOLGuidedPolicy  "+ SOL-guided steering": nominates hypotheses from the
                   SOL gap/bottleneck, ranks them with the paper's gap-aware
                   ROI, and consults cross-problem memory.

``capability`` in {mini, mid, max} models the three GPT tiers: it controls
proposal quality variance, toolchain failure rates, and gaming propensity
(the paper found *stronger* models game more — Sec. 6.3).
"""

from __future__ import annotations

import math
import random
import zlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from ..dsl.compiler import validate_dsl
from ..problems.base import Problem, Segment, Solution
from ..sol.hardware import SUBLANE_MULTIPLE

CAPABILITIES = ("mini", "mid", "max")

# toolchain failure / gaming / library-fallback propensities per capability
P_RAW_INVALID = {"mini": 0.60, "mid": 0.38, "max": 0.16}
P_RAW_GAME = {"mini": 0.015, "mid": 0.05, "max": 0.09}
P_RAW_PASSTHROUGH = {"mini": 0.10, "mid": 0.05, "max": 0.02}
P_DSL_GAME = {"mini": 0.03, "mid": 0.05, "max": 0.08}
P_DSL_PASSTHROUGH = {"mini": 0.14, "mid": 0.06, "max": 0.02}
P_BF16 = {"mini": 0.30, "mid": 0.55, "max": 0.80}
P_FUSE = {"mini": 0.35, "mid": 0.60, "max": 0.85}
EST_NOISE = {"mini": 0.55, "mid": 0.30, "max": 0.15}
# implementation-quality penalty for hand-written low-level code (lognormal
# mu, clamped at 1.0): weaker models emit correct-but-slow kernels; the DSL
# compiler removes this axis entirely (quality == 1.0) — the paper's
# representation mechanism.  The clamp encodes that the compiler's codegen is
# the per-configuration performance ceiling.
RAW_QUALITY_MU = {"mini": 1.00, "mid": 0.45, "max": 0.12}
RAW_QUALITY_SIGMA = 0.45


def sample_raw_quality(capability: str, rng: random.Random) -> float:
    return max(1.0, math.exp(rng.gauss(RAW_QUALITY_MU[capability],
                                       RAW_QUALITY_SIGMA)))


# probability the model actually follows the in-prompt MANTIS methodology on
# a given attempt (weaker models drift off-script; orchestration enforces the
# structure externally — paper Sec. 6.1.1)
P_ADHERE_INPROMPT = {"mini": 0.35, "mid": 0.65, "max": 0.95}

# probability a nominated hypothesis is mis-implemented (a feature dropped)
P_MISIMPLEMENT = {"mini": 0.25, "mid": 0.10, "max": 0.03}

# token cost model (documented constants; per-attempt LLM interaction)
TOKENS_RAW = 5200
TOKENS_DSL = 1900
TOKENS_PER_SEGMENT_RAW = 260
TOKENS_PER_SEGMENT_DSL = 90
TOKENS_SOL_ANALYSIS = 900
TOKENS_NOMINATE = 500
TOKENS_TRIAGE = 250
TOKENS_SUMMARIZE = 400
TOKENS_INPROMPT_OVERHEAD = 420

PRICE_PER_MTOK = {"mini": 0.25, "mid": 1.25, "max": 1.75}

_TILE_M = [64, 128, 256, 512]
_TILE_NK = [128, 256, 512, 1024]
_RAW_TILE = [32, 64, 96, 100, 128, 160, 192, 256, 300, 384, 512, 640, 1024]
_BLOCK_Q = [64, 128, 256, 512]
_BLOCK_KV = [128, 256, 512, 1024]
_CHUNKS = [32, 64, 128, 256, 512]
_STAGES = [1, 2, 3, 4]


@dataclass
class Hypothesis:
    solution: Solution
    description: str
    est_speedup: float = 1.0
    risk_impl: float = 1.0
    risk_perf: float = 1.0
    tokens: int = 0
    # raw-agent candidates may be invalid in ways only the toolchain sees
    toolchain_error: Optional[str] = None


def _ep_call(op: str) -> str:
    return {
        "relu": "relu()", "gelu": "gelu()", "silu": "silu()",
        "sigmoid": "sigmoid()", "tanh": "tanh()",
        "bias": "bias()", "residual_add": "residual_add()",
        "scale": "scale(value=0.5)", "clamp": "clamp(min=-1.0, max=1.0)",
        "custom": "custom('x * sigmoid(x)')",
    }.get(op, f"{op}()")


def emit_matmul_dsl(seg: Segment, *, dtype: str, tile: Tuple[int, int, int],
                    stages: int, epilogues: Sequence[str] = (),
                    split_k: int = 0) -> str:
    d = dict(seg.dims)
    batch = d.get("batch", 1)
    op = "gemm()" if batch == 1 else f"batched_gemm()"
    src = (f"{op}.with_dtype(input={dtype}, acc=fp32, output={dtype})"
           f".with_tile(m={tile[0]}, n={tile[1]}, k={tile[2]})"
           f".with_stages({stages})")
    if split_k > 1:
        src += f".with_split_k(mode=parallel, slices={split_k})"
    for ep in epilogues:
        src += f" >> {_ep_call(ep)}"
    return src


def emit_attention_dsl(seg: Segment, *, dtype: str, bq: int, bkv: int) -> str:
    d = dict(seg.dims)
    causal = "true" if d.get("causal") else "false"
    return (f"attention(causal={causal})"
            f".with_dtype(input={dtype}, acc=fp32, output={dtype})"
            f".with_block(q={bq}, kv={bkv})")


def emit_ssd_dsl(seg: Segment, *, dtype: str, chunk: int) -> str:
    d = dict(seg.dims)
    return (f"ssd_scan(d_state={d['n']})"
            f".with_dtype(input={dtype}, acc=fp32, output={dtype})"
            f".with_chunk({chunk})")


def emit_other_dsl(seg: Segment, dtype: str = "fp32") -> str:
    dts = f".with_dtype(input={dtype}, acc=fp32, output={dtype})"
    if seg.kind == "norm":
        norm = dict(seg.dims)["norm"]
        if norm == "softmax":
            return "softmax(axis=-1)" + dts
        return f"{norm}()" + dts
    if seg.kind == "eltwise":
        op = seg.epilogue_op or "relu"
        if op in ("bias", "residual_add", "per_channel_scale",
                  "per_row_scale", "per_col_scale", "custom"):
            # aux-broadcast epilogues only exist fused into matmul/conv;
            # the standalone pass is a plain elementwise HBM round-trip,
            # modeled with a placeholder scale op (cost-identical)
            op = "scale"
        return "eltwise()" + dts + f" >> {_ep_call(op)}"
    if seg.kind == "reduce":
        return "reduce(op=sum, axis=-1)" + dts
    if seg.kind == "scan":
        return "cumsum(axis=-1)" + dts
    if seg.kind == "xent":
        return "cross_entropy(reduction=mean)" + dts
    raise KeyError(seg.kind)


def build_solution(problem: Problem, *, dtype: str,
                   tiles: Dict[str, Tuple[int, int, int]],
                   blocks: Dict[str, Tuple[int, int]],
                   chunks: Dict[str, int],
                   stages: int, fuse: bool,
                   split_k: Dict[str, int] = {},
                   preconvert: bool = False,
                   note: str = "") -> Solution:
    """Assemble a Solution from per-segment choices.

    With ``fuse=True`` every fusable eltwise directly following a matmul is
    folded into that matmul's epilogue chain; norms after full-row-tile
    matmuls are marked fused too.
    """
    plans: Dict[str, str] = {}
    fused: Dict[str, bool] = {}
    segs = problem.segments
    i = 0
    prev_matmul: Optional[str] = None
    prev_tile_n: int = 0
    while i < len(segs):
        s = segs[i]
        if s.kind == "matmul":
            eps: List[str] = []
            j = i + 1
            while fuse and j < len(segs) and segs[j].kind == "eltwise" \
                    and segs[j].fusable:
                eps.append(segs[j].epilogue_op or "relu")
                fused[segs[j].name] = True
                j += 1
            tile = tiles.get(s.name, (256, 256, 512))
            src = emit_matmul_dsl(
                s, dtype=dtype, tile=tile, stages=stages, epilogues=eps,
                split_k=split_k.get(s.name, 0))
            if preconvert and dtype in ("bf16", "fp16"):
                src = (f"pipeline(transpose(input, NLC, NLC, fp32, {dtype}),"
                       f" {src})")
            plans[s.name] = src
            prev_matmul, prev_tile_n = s.name, tile[1]
            i = j
            continue
        if s.kind == "attention":
            bq, bkv = blocks.get(s.name, (128, 256))
            plans[s.name] = emit_attention_dsl(s, dtype=dtype, bq=bq,
                                               bkv=bkv)
            prev_matmul = None
            i += 1
            continue
        if s.kind == "ssd":
            plans[s.name] = emit_ssd_dsl(s, dtype=dtype,
                                         chunk=chunks.get(s.name, 128))
            prev_matmul = None
            i += 1
            continue
        if s.kind == "norm" and fuse and prev_matmul is not None \
                and dict(s.dims)["d"] <= prev_tile_n:
            fused[s.name] = True
            plans[s.name] = emit_other_dsl(s, dtype)
            i += 1
            continue
        plans[s.name] = emit_other_dsl(
            s, dtype if s.kind in ("norm", "eltwise") else "fp32")
        prev_matmul = None
        i += 1
    return Solution(plans=plans, fused=fused, note=note)


def _sub_of(dtype: str) -> int:
    return SUBLANE_MULTIPLE.get(dtype, 8)


class BasePolicy:
    name = "base"
    uses_dsl = False
    uses_sol = False

    def __init__(self, capability: str = "mid", seed: int = 0):
        assert capability in CAPABILITIES
        self.capability = capability
        self.seed = seed

    def rng_for(self, problem: Problem, attempt: int) -> random.Random:
        key = f"{self.name}|{self.capability}|{self.seed}|" \
              f"{problem.pid}|{attempt}"
        return random.Random(zlib.crc32(key.encode()))

    def tokens_per_attempt(self, problem: Problem) -> int:
        n = len(problem.segments)
        if self.uses_dsl:
            return TOKENS_DSL + TOKENS_PER_SEGMENT_DSL * n
        return TOKENS_RAW + TOKENS_PER_SEGMENT_RAW * n

    def propose(self, problem: Problem, ctx: Dict) -> Hypothesis:
        raise NotImplementedError


class RawPolicy(BasePolicy):
    """Low-level code generation: validity discovered by the toolchain."""

    name = "raw"
    uses_dsl = False

    def propose(self, problem: Problem, ctx: Dict) -> Hypothesis:
        rng = self.rng_for(problem, ctx.get("attempt", 0))
        tokens = self.tokens_per_attempt(problem)
        r = rng.random()
        if r < P_RAW_INVALID[self.capability]:
            kind = rng.choice(["template mismatch", "alignment violation",
                               "VMEM overflow", "accumulator dtype",
                               "grid/index bug", "numerical divergence"])
            return Hypothesis(Solution(note="invalid low-level attempt"),
                              description=f"raw code ({kind})",
                              tokens=tokens, toolchain_error=kind)
        r -= P_RAW_INVALID[self.capability]
        if r < P_RAW_GAME[self.capability]:
            return Hypothesis(
                Solution(flags=frozenset({"constant_output"}),
                         note="shortcut output"),
                description="raw code (algebraic shortcut)", tokens=tokens)
        r -= P_RAW_GAME[self.capability]
        if r < P_RAW_PASSTHROUGH[self.capability]:
            return Hypothesis(
                Solution(flags=frozenset({"passthrough"}),
                         note="library composition"),
                description="library-call composition", tokens=tokens)
        # a legitimate config from the wide, unvalidated space
        dtype = rng.choice(["fp32", "fp32", "bf16"]
                           if self.capability == "mini"
                           else ["fp32", "bf16", "bf16"])
        tiles, blocks, chunks = {}, {}, {}
        for s in problem.segments:
            if s.kind == "matmul":
                tiles[s.name] = (rng.choice(_RAW_TILE), rng.choice(_RAW_TILE),
                                 rng.choice(_RAW_TILE))
            elif s.kind == "attention":
                blocks[s.name] = (rng.choice(_RAW_TILE),
                                  rng.choice(_RAW_TILE))
            elif s.kind == "ssd":
                chunks[s.name] = rng.choice([24, 48] + _CHUNKS)
        sol = build_solution(problem, dtype=dtype, tiles=tiles, blocks=blocks,
                             chunks=chunks, stages=rng.choice(_STAGES),
                             fuse=rng.random() < 0.3,
                             note="raw low-level config")
        sol.quality = sample_raw_quality(self.capability, rng)
        # the raw agent does NOT pre-validate: invalid configs surface as
        # toolchain errors (burning this attempt)
        errs = []
        for name, src in sol.plans.items():
            errs = validate_dsl(src)
            if errs:
                break
        return Hypothesis(sol, description="raw low-level config",
                          tokens=tokens,
                          toolchain_error=str(errs[0]) if errs else None)


class DSLPolicy(BasePolicy):
    """Grammar-valid muPallas sampling with free static validation."""

    name = "dsl"
    uses_dsl = True

    def _sample_valid(self, problem: Problem, rng: random.Random,
                      ctx: Dict) -> Solution:
        cap = self.capability
        for _ in range(8):  # re-rolls are free (static validation)
            dtype = "bf16" if rng.random() < P_BF16[cap] else "fp32"
            sub = _sub_of(dtype)
            tiles, blocks, chunks = {}, {}, {}
            for s in problem.segments:
                if s.kind == "matmul":
                    m = rng.choice([t for t in _TILE_M if t % sub == 0])
                    tiles[s.name] = (m, rng.choice(_TILE_NK),
                                     rng.choice(_TILE_NK))
                elif s.kind == "attention":
                    blocks[s.name] = (rng.choice(_BLOCK_Q),
                                      rng.choice(_BLOCK_KV))
                elif s.kind == "ssd":
                    chunks[s.name] = rng.choice(_CHUNKS)
            sol = build_solution(
                problem, dtype=dtype, tiles=tiles, blocks=blocks,
                chunks=chunks, stages=rng.choice([2, 2, 3]),
                fuse=rng.random() < P_FUSE[cap], note="dsl sample")
            if all(not validate_dsl(src) for src in sol.plans.values()):
                return sol
        # deterministic safe fallback
        return build_solution(problem, dtype="bf16", tiles={}, blocks={},
                              chunks={}, stages=2, fuse=True,
                              note="dsl fallback")

    def propose(self, problem: Problem, ctx: Dict) -> Hypothesis:
        rng = self.rng_for(problem, ctx.get("attempt", 0))
        tokens = self.tokens_per_attempt(problem)
        r = rng.random()
        if r < P_DSL_GAME[self.capability]:
            flag = rng.choice(["constant_output", "input_exploit",
                               f"skip:{problem.segments[-1].name}"])
            return Hypothesis(
                Solution(flags=frozenset({flag}), note="dsl shortcut"),
                description=f"dsl shortcut ({flag})", tokens=tokens)
        r -= P_DSL_GAME[self.capability]
        if r < P_DSL_PASSTHROUGH[self.capability]:
            return Hypothesis(
                Solution(flags=frozenset({"passthrough"}),
                         note="library composition"),
                description="library-call composition", tokens=tokens)
        return Hypothesis(self._sample_valid(problem, rng, ctx),
                          description="dsl config sample", tokens=tokens)


class SOLGuidedPolicy(DSLPolicy):
    """MANTIS nomination: hypotheses targeted at the SOL bottleneck."""

    name = "sol_guided"
    uses_dsl = True
    uses_sol = True

    def nominate(self, problem: Problem, ctx: Dict,
                 n: int = 4) -> List[Hypothesis]:
        """Generate up to n targeted hypotheses with napkin-math estimates."""
        rng = self.rng_for(problem, ctx.get("attempt", 0))
        cap = self.capability
        noise = lambda: math.exp(rng.gauss(0.0, EST_NOISE[cap]))
        best: Optional[Solution] = ctx.get("best_solution")
        profile = ctx.get("profile")              # last Measurement
        report = ctx.get("sol_report")            # SOLReport or None
        memory = ctx.get("memory")
        tokens = self.tokens_per_attempt(problem) + TOKENS_NOMINATE

        cur = best or self._seed_solution(problem, memory)
        cur_cfg = self._config_of(cur, problem)
        hyps: List[Hypothesis] = []

        bottleneck = "compute"
        if report is not None:
            bottleneck = report.steering.bottleneck
        frac_compute = 0.6
        if profile is not None and profile.segments:
            tot = sum(s.t_total for s in profile.segments) or 1.0
            frac_compute = sum(min(s.t_compute, s.t_total)
                               for s in profile.segments) / tot

        def mk(sol, desc, est, ri, rp):
            # capability-dependent mis-implementation: a feature of the
            # hypothesis silently dropped (weaker models fumble the config)
            if rng.random() < P_MISIMPLEMENT[cap]:
                weak = self._config_of(sol, problem)
                if weak["fuse"]:
                    weak["fuse"] = False
                else:
                    weak["tiles"] = {k: (128, 128, 256)
                                     for k in weak["tiles"]}
                sol = self._rebuild(problem, weak)
                desc += " (mis-implemented)"
            hyps.append(Hypothesis(sol, desc, est_speedup=est * noise(),
                                   risk_impl=ri, risk_perf=rp,
                                   tokens=tokens))

        # H1: reduced precision (compute-bound lever; paper's TF32->FP16)
        if cur_cfg["dtype"] == "fp32":
            sol = self._rebuild(problem, cur_cfg, dtype="bf16")
            est = 1.0 + 2.2 * frac_compute if bottleneck == "compute" \
                else 1.0 + 0.6 * frac_compute
            mk(sol, "cast matmuls to bf16 (4x MXU rate, 2x bytes)",
               est, 1.1, 1.1)
        # H2: epilogue fusion (memory-bound lever)
        if not cur_cfg["fuse"]:
            sol = self._rebuild(problem, cur_cfg, fuse=True)
            n_fusable = sum(1 for s in problem.segments if s.fusable)
            mk(sol, f"fuse {n_fusable} elementwise tails into epilogues",
               1.0 + 0.25 * n_fusable, 1.0, 1.0)
        # H3: larger tiles (cut HBM re-reads)
        if any(t[0] < 512 or t[1] < 1024 for t in cur_cfg["tiles"].values()):
            tiles = {k: (min(512, t[0] * 2), min(1024, t[1] * 2),
                         max(t[2], 512))
                     for k, t in cur_cfg["tiles"].items()}
            sol = self._rebuild(problem, cur_cfg, tiles=tiles)
            mk(sol, "double tile sizes to cut operand re-reads",
               1.25 if bottleneck == "memory" else 1.1, 1.0, 1.2)
        # H3b: pre-convert operands to a bf16 scratch via pipeline transform
        # (one conversion pass buys 2 B/elem operand re-reads) — the DSL's
        # pipeline() feature targeting the re-read memory term
        if cur_cfg["dtype"] in ("bf16", "fp16") \
                and not cur_cfg.get("preconvert") and cur_cfg["tiles"]:
            sol = self._rebuild(problem, cur_cfg, preconvert=True)
            mk(sol, "pipeline-preconvert operands fp32->bf16 scratch",
               1.3 if bottleneck == "memory" else 1.1, 1.2, 1.2)
        # H4: full-row tile for norm fusion
        norm_rows = [dict(s.dims)["d"] for s in problem.segments
                     if s.kind == "norm"]
        if norm_rows and max(norm_rows) <= 2048 and not cur_cfg["fuse_norm"]:
            tiles = {k: (t[0], max(t[1], min(norm_rows)), t[2])
                     for k, t in cur_cfg["tiles"].items()}
            sol = self._rebuild(problem, cur_cfg, tiles=tiles, fuse=True)
            mk(sol, "full-row output tile to fuse trailing norm", 1.2,
               1.3, 1.3)
        # H5: attention block tuning
        if cur_cfg["blocks"]:
            blocks = {k: (256, 512) for k in cur_cfg["blocks"]}
            sol = self._rebuild(problem, cur_cfg, blocks=blocks)
            mk(sol, "larger attention q/kv blocks (fewer KV re-reads)",
               1.15, 1.0, 1.15)
        # H6: split-K for skinny outputs
        skinny = [s for s in problem.segments if s.kind == "matmul"
                  and dict(s.dims)["n"] <= 256]
        if skinny and bottleneck == "compute":
            sk = {s.name: 8 for s in skinny}
            sol = self._rebuild(problem, cur_cfg, split_k=sk)
            mk(sol, "parallel split-K for skinny GEMM (fill the pipeline)",
               1.6, 1.4, 1.4)
        # H7: SSD chunk tuning
        if cur_cfg["chunks"]:
            for c in (64, 256):
                chunks = {k: c for k in cur_cfg["chunks"]}
                sol = self._rebuild(problem, cur_cfg, chunks=chunks)
                mk(sol, f"SSD chunk={c} (quadratic-vs-sequential balance)",
                   1.1, 1.0, 1.2)
        # H8: deeper pipeline
        if cur_cfg["stages"] < 3:
            sol = self._rebuild(problem, cur_cfg, stages=3)
            mk(sol, "stages=3 (deeper HBM->VMEM lookahead)", 1.05, 1.0, 1.1)
        while len(hyps) < n:
            # pad with exploration samples so the matched attempt budget is
            # fully used even when few targeted hypotheses remain
            hyps.append(Hypothesis(self._sample_valid(problem, rng, ctx),
                                   description="exploration sample",
                                   est_speedup=1.02, tokens=tokens))
        rng.shuffle(hyps)
        return hyps[:n]

    # ---- config manipulation helpers -----------------------------------
    def _seed_solution(self, problem: Problem, memory) -> Solution:
        cfg = {"dtype": "fp32", "tiles": {}, "blocks": {}, "chunks": {},
               "stages": 2, "fuse": False, "split_k": {},
               "fuse_norm": False, "preconvert": False}
        if memory is not None:
            hint = memory.lookup(problem)
            if hint:
                cfg.update(hint)
        # SOL steering applied to trial 0: seed per-segment configs from the
        # persistent autotuning cache (measured on this device class), so
        # the first hypothesis starts from the tuned point instead of the
        # static library default.
        from ..tune import seed_hint_for_problem
        tuned = seed_hint_for_problem(problem, dtype=cfg["dtype"])
        for key in ("tiles", "blocks", "chunks"):
            cfg[key] = {**tuned[key], **cfg[key]}
        return self._rebuild(problem, cfg)

    def _config_of(self, sol: Solution, problem: Problem) -> Dict:
        """Parse the solution's plans back into a config dict."""
        from ..dsl.compiler import lower_dsl
        from ..dsl.ir import PipelineIR
        cfg = {"dtype": "fp32", "tiles": {}, "blocks": {}, "chunks": {},
               "stages": 2, "fuse": bool(sol.fused), "split_k": {},
               "fuse_norm": any(
                   sol.fused.get(s.name) for s in problem.segments
                   if s.kind == "norm"),
               "preconvert": False}
        for s in problem.segments:
            src = sol.plans.get(s.name)
            if not src:
                continue
            try:
                ir, _ = lower_dsl(src)
            except Exception:
                continue
            if isinstance(ir, PipelineIR):
                cfg["preconvert"] = True
                if not ir.kernel_stages:
                    continue
                ir = ir.kernel_stages[0]
            if s.kind == "matmul":
                if ir.tile:
                    cfg["tiles"][s.name] = (ir.tile.m, ir.tile.n, ir.tile.k)
                cfg["dtype"] = ir.dtypes.input
                cfg["stages"] = ir.stages
                if ir.split_k.mode == "parallel":
                    cfg["split_k"][s.name] = ir.split_k.slices
            elif s.kind == "attention":
                if ir.block:
                    cfg["blocks"][s.name] = (ir.block.q, ir.block.kv)
                cfg["dtype"] = ir.dtypes.input
            elif s.kind == "ssd":
                cfg["chunks"][s.name] = ir.chunk or 128
        for s in problem.segments:
            if s.kind == "matmul" and s.name not in cfg["tiles"]:
                cfg["tiles"][s.name] = (256, 256, 512)
            if s.kind == "attention" and s.name not in cfg["blocks"]:
                cfg["blocks"][s.name] = (128, 256)
            if s.kind == "ssd" and s.name not in cfg["chunks"]:
                cfg["chunks"][s.name] = 128
        return cfg

    def _rebuild(self, problem: Problem, cfg: Dict, **overrides) -> Solution:
        c = dict(cfg)
        c.update(overrides)
        sub = _sub_of(c["dtype"])
        tiles = {k: (max(_ceil := ((t[0] + sub - 1) // sub) * sub, sub),
                     t[1], t[2])
                 for k, t in c["tiles"].items()}
        return build_solution(
            problem, dtype=c["dtype"], tiles=tiles, blocks=c["blocks"],
            chunks=c["chunks"], stages=c["stages"], fuse=c["fuse"],
            split_k=c.get("split_k", {}),
            preconvert=c.get("preconvert", False), note="sol-guided")

    def propose(self, problem: Problem, ctx: Dict) -> Hypothesis:
        hyps = self.nominate(problem, ctx, n=1)
        return hyps[0]


def make_policy(kind: str, capability: str, seed: int = 0) -> BasePolicy:
    cls = {"raw": RawPolicy, "dsl": DSLPolicy,
           "sol_guided": SOLGuidedPolicy}[kind]
    return cls(capability=capability, seed=seed)
