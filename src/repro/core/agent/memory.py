"""Cross-problem memory (paper Sec. 4.2, Summarize phase).

Distilled lessons from evaluated hypotheses are persisted keyed by a
problem-family signature, so the Nominate phase of later problems can
warm-start from concise, reusable optimization patterns.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from ..problems.base import Problem


def family_signature(problem: Problem) -> Tuple[str, ...]:
    kinds = sorted({s.kind for s in problem.segments})
    has_fusable = any(s.fusable for s in problem.segments)
    return tuple(kinds) + (("fusable",) if has_fusable else ())


@dataclass
class Lesson:
    signature: Tuple[str, ...]
    config_hint: Dict
    speedup: float
    summary: str = ""


@dataclass
class CrossProblemMemory:
    lessons: List[Lesson] = field(default_factory=list)

    def record(self, problem: Problem, config_hint: Dict, speedup: float,
               summary: str = "") -> None:
        # keep only portable keys (no per-segment names)
        portable = {
            "dtype": config_hint.get("dtype", "fp32"),
            "stages": config_hint.get("stages", 2),
            "fuse": config_hint.get("fuse", False),
        }
        self.lessons.append(Lesson(family_signature(problem), portable,
                                   speedup, summary))

    def lookup(self, problem: Problem) -> Optional[Dict]:
        sig = family_signature(problem)
        candidates = [l for l in self.lessons if l.signature == sig]
        if not candidates:
            # fall back: same dominant kind
            dom = max(problem.segments, key=lambda s: s.flops()).kind
            candidates = [l for l in self.lessons if dom in l.signature]
        if not candidates:
            return None
        best = max(candidates, key=lambda l: l.speedup)
        return dict(best.config_hint)
