"""Analytic TPU v5e performance model — the deterministic "profiler".

This container has no TPU, so candidate kernels are "measured" against a
first-principles model of the chip (the paper's NCU role).  The model is
deliberately structural: every term comes from the hardware spec and the
kernel configuration, so the optimization landscape has real, explainable
optima the agents can climb toward:

  * tile quantization waste          (padded M/N/K)
  * MXU alignment efficiency         (tiles vs the 128x128 systolic array)
  * HBM re-read amplification        (A re-read N/bn times, B re-read M/bm —
                                      the classic tile-size trade-off)
  * compute/DMA overlap              (max + min/stages pipelining)
  * small-grid utilization           (too few tiles to fill the pipeline;
                                      split-K parallel buys it back for
                                      skinny shapes at extra reduce traffic)
  * epilogue fusion                  (fused elementwise tails are free;
                                      unfused ones pay a full HBM round trip)
  * full-row-tile norm fusion        (a norm after a GEMM fuses only when
                                      tile n spans the whole row)
  * dtype                            (bf16 2x storage & 4x fp32 MXU rate)

The same model also produces the baseline runtime ``t_ref``: the reference
framework executes every segment separately, in fp32, with library-default
tiles, and materializes attention scores — the TPU analogue of the paper's
eager-PyTorch baseline.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from ..dsl.compiler import lower_dsl
from ..dsl.errors import DSLError
from ..dsl.ir import KernelIR, PipelineIR
from ..problems.base import Problem, Segment, Solution
from ..sol.hardware import (ChipSpec, TPU_V5E, ceil_to as _ceil_to,
                            dtype_bytes)

LAUNCH_OVERHEAD = 5e-6        # per optimized-kernel launch
BASELINE_OVERHEAD = 12e-6     # per baseline framework op dispatch


def _align_eff(x: int, native: int = 128) -> float:
    """Fraction of the systolic array doing useful work for dim size x."""
    if x <= 0:
        return 1e-3
    return x / _ceil_to(x, native)


def _grid_util(tiles: float) -> float:
    """Launch too few tiles and the HBM->VMEM pipeline never fills."""
    return tiles / (tiles + 2.0)


@dataclass
class SegmentCost:
    name: str
    t_compute: float
    t_memory: float
    t_total: float
    fused: bool = False
    note: str = ""


@dataclass
class Measurement:
    """One candidate's 'profile' (the NCU-report analogue)."""

    runtime_s: float
    ok: bool
    error: str = ""
    segments: List[SegmentCost] = field(default_factory=list)

    @property
    def breakdown(self) -> Dict[str, float]:
        return {s.name: s.t_total for s in self.segments}


class CostModel:
    def __init__(self, chip: ChipSpec = TPU_V5E):
        self.chip = chip

    # ------------------------------------------------------------------
    def _peak(self, dtype: str) -> float:
        try:
            return self.chip.peak(dtype)
        except KeyError:
            return self.chip.peak("fp32")

    def _combine(self, tc: float, tm: float, stages: int,
                 tiles: float) -> float:
        overlap = max(tc, tm) + min(tc, tm) / max(stages, 1)
        return overlap / _grid_util(tiles) + LAUNCH_OVERHEAD

    # ------------------------------------------------------------------
    # NOTE on dtypes: the problem's tensors are fp32 *as allocated* (the
    # KernelBench convention the paper follows) — reduced-precision kernels
    # cast on-chip, so HBM traffic stays fp32 and only the compute peak
    # changes (paper Sec. 4.1, "FP16 augmentation").  All byte terms below
    # therefore use 4 B/elem regardless of the kernel's compute dtype.
    _IO_BYTES = 4

    def matmul_cost(self, segment: Segment, *, bm: int, bn: int, bk: int,
                    in_dtype: str, out_dtype: str, stages: int,
                    split_k: int = 1, fused_eltwise_flops: float = 0.0,
                    extra_full_aux: int = 0,
                    operands_preconverted: bool = False) -> SegmentCost:
        d = dict(segment.dims)
        m, n, k = d["m"], d["n"], d["k"]
        batch = d.get("batch", 1)
        b_in = b_out = self._IO_BYTES
        conversion_bytes = 0.0
        if operands_preconverted and in_dtype in ("bf16", "fp16"):
            # pipeline(transpose(..., fp32, bf16), gemm...): one-time
            # fp32->bf16 scratch conversion, then 2 B/elem operand re-reads
            b_in = dtype_bytes(in_dtype)
            conversion_bytes = batch * (m * k + k * n) * (4 + b_in)
        mp, np_, kp = _ceil_to(m, bm), _ceil_to(n, bn), _ceil_to(k, bk)
        flops = 2.0 * batch * mp * np_ * kp
        eff = _align_eff(min(bm, mp)) * _align_eff(min(bn, np_))
        t_c = flops / (self._peak(in_dtype) * eff)

        n_i, n_j = mp // bm, np_ // bn
        a_bytes = batch * mp * kp * b_in * n_j
        b_bytes = batch * kp * np_ * b_in * n_i
        c_bytes = batch * mp * np_ * b_out
        aux_bytes = extra_full_aux * batch * mp * np_ * b_in
        t_m = (a_bytes + b_bytes + c_bytes + aux_bytes + conversion_bytes) \
            / self.chip.hbm_bandwidth

        tiles = batch * n_i * n_j * max(split_k, 1)
        t = self._combine(t_c, t_m, stages, tiles)
        if split_k > 1:
            # partial accumulator writes + final reduction pass
            red = (split_k * batch * mp * np_ * 4 * 2) / self.chip.hbm_bandwidth
            t += red
        return SegmentCost(segment.name, t_c, t_m, t)

    def attention_cost(self, segment: Segment, *, bq: int, bkv: int,
                       in_dtype: str, stages: int = 2,
                       materialize_scores: bool = False) -> SegmentCost:
        d = dict(segment.dims)
        b, h, sq, skv, hd = d["b"], d["h"], d["sq"], d["skv"], d["d"]
        h_kv = d.get("h_kv", h)
        causal = bool(d.get("causal", False))
        b_in = self._IO_BYTES
        sqp, skvp = _ceil_to(sq, bq), _ceil_to(skv, bkv)
        eff_causal = 0.55 if causal else 1.0
        flops = (4.0 * b * h * sqp * skvp * hd + 5.0 * b * h * sqp * skvp) \
            * eff_causal
        eff = (_align_eff(min(bq, sqp)) * _align_eff(min(bkv, skvp))
               * _align_eff(hd))
        t_c = flops / (self._peak(in_dtype) * eff)

        if materialize_scores:
            # baseline: scores written + read twice (softmax) in fp32
            sc = b * h * sq * skv * 4
            io = (b * sq * h * hd * 2 + 2 * b * skv * h_kv * hd) * b_in \
                + 4 * sc
        else:
            n_qb = sqp // bq
            io = (b * sq * h * hd * 2 * b_in
                  + 2 * b * skvp * h_kv * hd * b_in * n_qb)
        t_m = io / self.chip.hbm_bandwidth
        tiles = b * h * (sqp // bq)
        t = self._combine(t_c, t_m, stages, tiles)
        return SegmentCost(segment.name, t_c, t_m, t)

    def ssd_cost(self, segment: Segment, *, chunk: int, in_dtype: str,
                 stages: int = 2) -> SegmentCost:
        d = dict(segment.dims)
        b, t_len, h, p, n = d["b"], d["t"], d["h"], d["p"], d["n"]
        b_in = self._IO_BYTES
        c = max(chunk, 8)
        tp = _ceil_to(t_len, c)
        # per-token matmul work: intra-chunk quadratic + state update
        flops = b * h * tp * (2.0 * c * (n + p) + 6.0 * n * p)
        eff = (_align_eff(min(c, 128)) * _align_eff(n) * _align_eff(p))
        t_c = flops / (self._peak(in_dtype) * eff)
        io = (b * h * tp * (p + 1) + 2 * b * h * tp * n) * b_in \
            + b * h * tp * p * b_in
        t_m = io / self.chip.hbm_bandwidth
        tiles = b * h          # chunk loop is sequential per (b, h)
        t = self._combine(t_c, t_m, stages, tiles)
        # sequential chunk-to-chunk dependency latency
        t += (tp / c) * 1e-7
        return SegmentCost(segment.name, t_c, t_m, t)

    def memory_bound_cost(self, segment: Segment, *, in_dtype: str,
                          out_dtype: str, overhead: float = LAUNCH_OVERHEAD,
                          rw_factor: float = 1.0) -> SegmentCost:
        inb, outb = segment.io_bytes(self._IO_BYTES, self._IO_BYTES)
        t_m = (inb + outb) * rw_factor / self.chip.hbm_bandwidth
        t_c = segment.flops() / self._peak("fp32")
        t = max(t_m, t_c) + overhead
        return SegmentCost(segment.name, t_c, t_m, t)

    # ------------------------------------------------------------------
    def baseline(self, problem: Problem) -> Measurement:
        """t_ref: unfused fp32 library execution (the PyTorch analogue)."""
        segs: List[SegmentCost] = []
        for s in problem.segments:
            if s.kind == "matmul":
                c = self.matmul_cost(s, bm=512, bn=512, bk=512,
                                     in_dtype="fp32", out_dtype="fp32",
                                     stages=2)
            elif s.kind == "attention":
                c = self.attention_cost(s, bq=512, bkv=512, in_dtype="fp32",
                                        materialize_scores=True)
            elif s.kind == "ssd":
                # baseline: sequential scan, no chunking (tiny matmuls)
                c = self.ssd_cost(s, chunk=16, in_dtype="fp32")
                c = SegmentCost(c.name, c.t_compute, c.t_memory,
                                c.t_total * 1.5, note="sequential scan")
            elif s.kind == "scan":
                c = self.memory_bound_cost(s, in_dtype="fp32",
                                           out_dtype="fp32", rw_factor=1.15,
                                           overhead=BASELINE_OVERHEAD)
            elif s.kind == "norm":
                # eager normalization = multiple HBM passes (max/sub-exp-sum/
                # div for softmax; stats + normalize for LN) vs one fused pass
                c = self.memory_bound_cost(s, in_dtype="fp32",
                                           out_dtype="fp32", rw_factor=2.2,
                                           overhead=BASELINE_OVERHEAD)
            else:
                c = self.memory_bound_cost(s, in_dtype="fp32",
                                           out_dtype="fp32",
                                           overhead=BASELINE_OVERHEAD)
            segs.append(SegmentCost(c.name, c.t_compute, c.t_memory,
                                    c.t_total + BASELINE_OVERHEAD
                                    - LAUNCH_OVERHEAD, note=c.note))
        return Measurement(runtime_s=sum(c.t_total for c in segs), ok=True,
                           segments=segs)

    # ------------------------------------------------------------------
    def evaluate(self, problem: Problem, solution: Solution) -> Measurement:
        """Profile a candidate solution (the compile+run+profile analogue)."""
        # Gaming shortcuts: fast, but usually NOT fast enough to beat the
        # physical bound — most are caught by the game detector rather than
        # the SOL-ceiling detector (paper Sec. 6.3).  The exploit's speed is
        # a deterministic function of (problem, exploit) so inherited
        # attempts reproduce it exactly.
        def _u(lo: float, hi: float) -> float:
            import zlib
            key = f"{problem.pid}|{sorted(solution.flags)}|{solution.note}"
            h = zlib.crc32(key.encode()) & 0xFFFF
            return lo + (hi - lo) * (h / 0xFFFF)

        from ..sol.report import make_report
        if "constant_output" in solution.flags or \
                any(f.startswith("skip:") for f in solution.flags):
            ceil = make_report(problem.pid,
                               problem.characterization()).t_sol_ceiling
            t = max(ceil * _u(0.5, 3.0), LAUNCH_OVERHEAD)
            return Measurement(runtime_s=t, ok=True,
                               segments=[SegmentCost("shortcut", 0, t, t)])
        if "input_exploit" in solution.flags:
            ceil = make_report(problem.pid,
                               problem.characterization()).t_sol_ceiling
            t = max(ceil * _u(0.2, 1.0), LAUNCH_OVERHEAD)
            return Measurement(runtime_s=t, ok=True,
                               segments=[SegmentCost("exploit", 0, 0, t)])
        if solution.is_passthrough():
            # compiled library composition: op fusion beats the eager
            # baseline without any agent-authored kernel
            m = self.baseline(problem)
            t = m.runtime_s * _u(0.35, 0.8)
            return Measurement(runtime_s=t, ok=True, segments=m.segments)

        segs: List[SegmentCost] = []
        prev_matmul: Optional[Tuple[Segment, KernelIR]] = None
        for s in problem.segments:
            fused = solution.fused.get(s.name, False)
            plan_src = solution.plans.get(s.name)
            ir: Optional[KernelIR] = None
            preconverted = False
            if plan_src is not None:
                try:
                    ir_prog, _ = lower_dsl(plan_src)
                except DSLError as e:
                    return Measurement(runtime_s=float("inf"), ok=False,
                                       error=f"{s.name}: {e}")
                if isinstance(ir_prog, PipelineIR):
                    ir = ir_prog.kernel_stages[0]
                    preconverted = any(
                        getattr(st, "dst_dtype", None) in ("bf16", "fp16")
                        for st in ir_prog.stages)
                else:
                    ir = ir_prog

            if s.kind in ("matmul",):
                if ir is None:
                    return Measurement(runtime_s=float("inf"), ok=False,
                                       error=f"{s.name}: missing plan")
                tile = ir.tile
                bm, bn, bk = ((tile.m, tile.n, tile.k) if tile
                              else (256, 256, 512))
                n_full_aux = sum(1 for ep in ir.epilogues
                                 if ep.name in ("residual_add",)
                                 or (ep.name == "custom" and any(
                                     k == "full" for _, k in ep.inputs)))
                fused_fl = sum(t.flops() for t in problem.segments
                               if t.fusable and
                               solution.fused.get(t.name, False))
                slices = (ir.split_k.slices
                          if ir.split_k.mode == "parallel" else 1)
                c = self.matmul_cost(
                    s, bm=bm, bn=bn, bk=bk, in_dtype=ir.dtypes.input,
                    out_dtype=ir.dtypes.output, stages=ir.stages,
                    split_k=slices, fused_eltwise_flops=fused_fl,
                    extra_full_aux=n_full_aux,
                    operands_preconverted=preconverted)
                prev_matmul = (s, ir)
            elif s.kind == "attention":
                if ir is None:
                    return Measurement(runtime_s=float("inf"), ok=False,
                                       error=f"{s.name}: missing plan")
                bq, bkv = ((ir.block.q, ir.block.kv) if ir.block
                           else (128, 128))
                c = self.attention_cost(s, bq=bq, bkv=bkv,
                                        in_dtype=ir.dtypes.input,
                                        stages=ir.stages)
                prev_matmul = (s, ir)
            elif s.kind == "ssd":
                if ir is None:
                    return Measurement(runtime_s=float("inf"), ok=False,
                                       error=f"{s.name}: missing plan")
                c = self.ssd_cost(s, chunk=ir.chunk or 128,
                                  in_dtype=ir.dtypes.input,
                                  stages=ir.stages)
                prev_matmul = None
            elif s.kind == "eltwise":
                if fused and s.fusable and prev_matmul is not None:
                    segs.append(SegmentCost(s.name, 0.0, 0.0, 0.0,
                                            fused=True, note="epilogue"))
                    continue
                dt_in = ir.dtypes.input if ir else "fp32"
                dt_out = ir.dtypes.output if ir else "fp32"
                c = self.memory_bound_cost(s, in_dtype=dt_in,
                                           out_dtype=dt_out)
                prev_matmul = None
            elif s.kind == "norm":
                # full-row-tile fusion: free only if the previous matmul's
                # tile n covered the whole row
                if fused and prev_matmul is not None:
                    pseg, pir = prev_matmul
                    row = dict(s.dims)["d"]
                    tile_n = pir.tile.n if pir.tile else 256
                    if pseg.kind == "matmul" and tile_n >= row:
                        segs.append(SegmentCost(s.name, 0.0, 0.0, 0.0,
                                                fused=True,
                                                note="full-row tile"))
                        continue
                dt_in = ir.dtypes.input if ir else "fp32"
                dt_out = ir.dtypes.output if ir else "fp32"
                c = self.memory_bound_cost(s, in_dtype=dt_in,
                                           out_dtype=dt_out)
                prev_matmul = None
            elif s.kind == "scan":
                dt_in = ir.dtypes.input if ir else "fp32"
                c = self.memory_bound_cost(s, in_dtype=dt_in,
                                           out_dtype=dt_in, rw_factor=1.15)
                prev_matmul = None
            else:
                dt_in = ir.dtypes.input if ir else "fp32"
                c = self.memory_bound_cost(s, in_dtype=dt_in,
                                           out_dtype="fp32")
                prev_matmul = None
            segs.append(c)
        runtime = sum(c.t_total for c in segs) * max(solution.quality, 1e-3)
        return Measurement(runtime_s=runtime, ok=True, segments=segs)


def cite_fusion_report(report) -> str:
    """One-line citation of a compile artifact's fusion report
    (``CompiledKernel.fusion``) for agent run logs / hypothesis notes.

    The fusion pass is the compiler-side ground truth for the model's
    epilogue-fusion and full-row-norm terms above: citing its per-edge
    predicted bytes-saved ties an agent's "fuse these stages" hypothesis
    to the SOL memory-traffic estimate that justified it.
    """
    if report is None:
        return "no fusion report (single-kernel program)"
    fused = [d for d in report.decisions if d.fused]
    declined = [d for d in report.decisions if not d.fused]
    parts = []
    for d in fused:
        if d.bytes_saved is not None:
            parts.append(f"{d.pattern} saves {d.bytes_saved / 1e3:.1f} KB"
                         + (f" ({100 * d.headroom:.0f}% of unfused traffic)"
                            if d.headroom else ""))
        else:
            parts.append(d.pattern)
    head = f"fused {len(fused)}/{len(report.decisions)} edges"
    if parts:
        head += ": " + "; ".join(parts)
    if declined:
        head += f"; declined: " + "; ".join(
            f"{d.pattern} ({d.reason})" for d in declined[:2])
    return head


def cite_drift_report(report: Optional[Dict]) -> str:
    """One-line citation of a drift report
    (``core.obs.DriftDetector.report()``) for agent run logs / hypothesis
    notes — the observability twin of ``cite_fusion_report``.

    A drifting op tells the agent its evidence base is suspect: a
    ``below_bound`` op means measurements beat the physical SOL bound
    (the gaming signal the integrity pipeline flags per-attempt), an
    ``above_model`` op means the calibrated cost model is stale and its
    predictions should not steer hypothesis ranking until re-calibrated.
    """
    if not report:
        return "no drift report (no SOL-attributed observations yet)"
    drifting = {op: r for op, r in report.items() if r.get("drifting")}
    if not drifting:
        return (f"no sustained drift across {len(report)} op(s): "
                f"predictions and measurements agree within tolerance")
    parts = [
        f"{op} {r['direction']} (measured/predicted "
        f"{r['mean_ratio']:.3g} over {r['window_n']} samples, {r['unit']})"
        for op, r in drifting.items()
    ]
    return (f"DRIFT on {len(drifting)}/{len(report)} op(s): "
            + "; ".join(parts))


def cite_quant_report(report: Optional[Dict]) -> str:
    """One-line citation of a quantization headroom report
    (``core.tune.quant_report``) for agent run logs / hypothesis notes —
    the quantized twin of ``cite_fusion_report``.

    Ties an agent's "quantize this weight" hypothesis to the dtype-aware
    SOL byte accounting that justified it (predicted weight-bytes saved as
    a fraction of the op's HBM traffic) and to the measured error-budget
    verdict the tuning cache holds for the shape bucket.
    """
    if not report:
        return "no quantization report (op not a weight matmul)"
    head = (f"{report['op']}{tuple(report['dims'])}: "
            f"{report['wdtype']} weights save "
            f"{report['bytes_saved'] / 1e3:.1f} KB "
            f"({100 * report['headroom']:.0f}% of op HBM traffic)")
    verdict = report.get("verdict", "unmeasured")
    if verdict == "unmeasured":
        head += (f"; error budget {report['budget']:.3g} rel "
                 f"(unmeasured — sweep to confirm)")
    elif verdict == "vetoed":
        err = report.get("rel_err")
        head += ("; VETOED by measured error"
                 + (f" {err:.3g}" if err is not None else "")
                 + f" > budget {report['budget']:.3g}")
    else:
        err = report.get("rel_err")
        head += (f"; measured verdict {verdict}"
                 + (f" (rel err {err:.3g} within budget "
                    f"{report['budget']:.3g})" if err is not None else ""))
    return head


def cite_gate_verdict(verdict: Optional[Dict]) -> str:
    """One-line citation of an integrity-gate verdict
    (``core.integrity.gate.Verdict.as_dict()``) for agent run logs /
    hypothesis notes — the enforcement twin of ``cite_drift_report``.

    A quarantined attempt contributes zero to every score the agent
    optimizes (``Attempt.scored_speedup``), so the citation tells the
    agent *why* its fast-looking candidate earned nothing: which detector
    fired and what evidence it recorded.
    """
    if not verdict:
        return "no gate verdict (attempt not yet reviewed)"
    decision = verdict.get("decision", "accept")
    if decision == "accept":
        return "gate: accepted (all integrity detectors passed)"
    reasons = verdict.get("reason_codes") or []
    ev = verdict.get("evidence") or {}
    parts = []
    for code in reasons:
        if code == "sol_impossible":
            parts.append("measurement beats the SOL bound "
                         "(physically impossible)")
        elif code == "oracle_mismatch":
            parts.append("output disagrees with the reference oracle")
        elif code == "hlo_folded":
            parts.append("XLA folded the benchmark away "
                         "(dead code / constants)")
        elif code == "timer_cheat":
            parts.append("timed clock disagrees with the monotonic clock")
        elif code == "dispatch_mismatch":
            parts.append("dispatch count disagrees with the step counter")
        elif code == "ledger_blocked":
            parts.append("config already on the quarantine ledger")
        else:
            parts.append(code)
    head = f"gate: {decision.upper()} — " + ("; ".join(parts) or "unlabeled")
    label = ev.get("label")
    if label:
        head += f" (pipeline label: {label})"
    return head + "; this attempt scores zero"
