"""The experimental variant matrix (paper Table 2 + Table 3 ablations)."""

from __future__ import annotations

from dataclasses import replace
from typing import Dict, Iterable, List, Optional

from .mantis import Agent, AgentConfig
from .memory import CrossProblemMemory
from .costmodel import CostModel
from .runlog import RunLog

# Table 2: three controllers x with/without the DSL, matched 40 attempts.
VARIANTS: Dict[str, AgentConfig] = {
    "mi_raw": AgentConfig(representation="raw", steering=None),
    "mi_dsl": AgentConfig(representation="dsl", steering=None),
    "inprompt_raw": AgentConfig(representation="raw", steering="in_prompt"),
    "inprompt_dsl": AgentConfig(representation="dsl", steering="in_prompt"),
    "orch_raw": AgentConfig(representation="raw", steering="orchestrated"),
    "orch_dsl": AgentConfig(representation="dsl", steering="orchestrated"),
}

# Table 3: component ablations of orchestrated MANTIS (+DSL).
ABLATIONS: Dict[str, AgentConfig] = {
    "mantis": AgentConfig(representation="dsl", steering="orchestrated"),
    "mntis_noA": AgentConfig(representation="dsl", steering="orchestrated",
                             components={"M", "N", "T", "I", "S"}),
    "manis_noT": AgentConfig(representation="dsl", steering="orchestrated",
                             components={"M", "A", "N", "I", "S"}),
    "manti_noS": AgentConfig(representation="dsl", steering="orchestrated",
                             components={"M", "A", "N", "T", "I"},
                             cross_problem_memory=False),
    "mantis_noXmem": AgentConfig(representation="dsl",
                                 steering="orchestrated",
                                 cross_problem_memory=False),
}


def run_variant(cfg: AgentConfig, problems: Iterable, *,
                capability: str = "mid", seed: int = 0,
                cost_model: Optional[CostModel] = None) -> List[RunLog]:
    """Run one agent variant over a problem list with shared memory."""
    cfg = replace(cfg, capability=capability, seed=seed)
    memory = CrossProblemMemory()
    agent = Agent(cfg, cost_model=cost_model, memory=memory)
    return [agent.optimize(p) for p in problems]


def best_steering_variant(capability: str) -> str:
    """Paper Sec. 6.1.1: orchestrated wins except GPT-5.2 (+DSL) where
    in-prompt is ahead — mirrored on our capability tiers."""
    return "inprompt_dsl" if capability == "max" else "orch_dsl"
