"""Attempt traces — the artifact the scheduler replays and the integrity
pipeline audits (paper Sec. 5.7: "offline replay of existing run logs")."""

from __future__ import annotations

import json
import math
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional


@dataclass
class Attempt:
    index: int
    phase: str                   # measure|implement|...
    description: str
    tokens: int
    ok: bool                     # toolchain succeeded (compile+run)
    runtime_s: float             # inf when failed
    speedup: float               # t_ref / runtime (0 when failed)
    flags: List[str] = field(default_factory=list)
    inherited: bool = False      # inherited a prior attempt's exploit
    error: str = ""
    # filled by the integrity pipeline:
    label: str = ""              # no_issues|minor|sol_ceiling|pytorch_only|
    #                              original_gaming|inherited_gaming
    hypothesis: str = ""


@dataclass
class RunLog:
    problem_id: str
    variant: str
    capability: str
    seed: int
    t_ref: float
    t_sol: float                 # steering bound (fp32 formulation)
    t_sol_ceiling: float         # bf16 ceiling (scheduling/integrity)
    attempts: List[Attempt] = field(default_factory=list)

    # ---- summaries --------------------------------------------------------
    def best_speedup(self, upto: Optional[int] = None,
                     accepted_only: bool = False) -> float:
        best = 0.0
        for a in self.attempts[:upto]:
            if not a.ok or not math.isfinite(a.runtime_s):
                continue
            if accepted_only and a.label not in ("", "no_issues", "minor"):
                continue
            best = max(best, a.speedup)
        return best

    def best_runtime(self, upto: Optional[int] = None,
                     accepted_only: bool = False) -> float:
        s = self.best_speedup(upto, accepted_only)
        return self.t_ref / s if s > 0 else float("inf")

    @property
    def total_tokens(self) -> int:
        return sum(a.tokens for a in self.attempts)

    def tokens_upto(self, upto: int) -> int:
        return sum(a.tokens for a in self.attempts[:upto])

    @property
    def n_attempts(self) -> int:
        return len(self.attempts)

    # ---- serialization -------------------------------------------------
    def to_json(self) -> Dict:
        d = asdict(self)
        for a in d["attempts"]:
            if not math.isfinite(a["runtime_s"]):
                a["runtime_s"] = None
        return d

    @classmethod
    def from_json(cls, d: Dict) -> "RunLog":
        attempts = []
        for a in d["attempts"]:
            a = dict(a)
            if a["runtime_s"] is None:
                a["runtime_s"] = float("inf")
            attempts.append(Attempt(**a))
        d = dict(d)
        d["attempts"] = attempts
        return cls(**d)


def save_runlogs(logs: List[RunLog], path: str) -> None:
    with open(path, "w") as f:
        json.dump([l.to_json() for l in logs], f)


def load_runlogs(path: str) -> List[RunLog]:
    with open(path) as f:
        return [RunLog.from_json(d) for d in json.load(f)]
