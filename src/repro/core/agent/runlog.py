"""Attempt traces — the artifact the scheduler replays and the integrity
pipeline audits (paper Sec. 5.7: "offline replay of existing run logs")."""

from __future__ import annotations

import json
import math
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional


@dataclass
class Attempt:
    index: int
    phase: str                   # measure|implement|...
    description: str
    tokens: int
    ok: bool                     # toolchain succeeded (compile+run)
    runtime_s: float             # inf when failed
    speedup: float               # t_ref / runtime (0 when failed)
    flags: List[str] = field(default_factory=list)
    inherited: bool = False      # inherited a prior attempt's exploit
    error: str = ""
    # filled by the integrity pipeline:
    label: str = ""              # no_issues|minor|sol_ceiling|pytorch_only|
    #                              original_gaming|inherited_gaming
    hypothesis: str = ""
    # the integrity gate's recorded decision over this attempt (Verdict
    # .as_dict(), plus a "citation" line for the agent prompt); None until
    # the gate reviewed it
    verdict: Optional[Dict] = None

    @property
    def scored_speedup(self) -> float:
        """The speedup this attempt is allowed to claim: zero unless the
        toolchain succeeded, the runtime is finite, AND the integrity gate
        accepted it — a gamed attempt scores nothing, however fast."""
        if not self.ok or not math.isfinite(self.runtime_s):
            return 0.0
        if self.label not in ("", "no_issues", "minor"):
            return 0.0
        if self.verdict is not None \
                and self.verdict.get("decision") not in (None, "accept"):
            return 0.0
        return self.speedup


@dataclass
class RunLog:
    problem_id: str
    variant: str
    capability: str
    seed: int
    t_ref: float
    t_sol: float                 # steering bound (fp32 formulation)
    t_sol_ceiling: float         # bf16 ceiling (scheduling/integrity)
    attempts: List[Attempt] = field(default_factory=list)

    # ---- recording ------------------------------------------------------
    def record(self, attempt: Attempt) -> Attempt:
        """Append an attempt and emit an ``agent.attempt`` trace event.

        The event's SOL attribution holds runtime against the bf16 ceiling
        bound: a sustained windowed mean *below* the ceiling is the same
        physically-implausible signal the integrity pipeline's sol_ceiling
        detector flags per-attempt.
        """
        self.attempts.append(attempt)
        from ..obs.trace import get_tracer

        tr = get_tracer()
        if tr.enabled:
            sol = None
            if attempt.ok and math.isfinite(attempt.runtime_s) \
                    and self.t_sol_ceiling > 0:
                sol = {"t_sol_s": self.t_sol_ceiling,
                       "predicted": self.t_sol_ceiling,
                       "measured": attempt.runtime_s,
                       "op": f"agent.{self.problem_id}",
                       "calibrated": False}
            tr.event("agent.attempt", cat="agent", sol=sol,
                     problem_id=self.problem_id, variant=self.variant,
                     index=attempt.index, phase=attempt.phase,
                     ok=attempt.ok, runtime_s=attempt.runtime_s,
                     speedup=attempt.speedup, tokens=attempt.tokens,
                     flags=list(attempt.flags), error=attempt.error)
        return attempt

    # ---- summaries --------------------------------------------------------
    def best_speedup(self, upto: Optional[int] = None,
                     accepted_only: bool = False) -> float:
        best = 0.0
        for a in self.attempts[:upto]:
            if not a.ok or not math.isfinite(a.runtime_s):
                continue
            if accepted_only and a.label not in ("", "no_issues", "minor"):
                continue
            best = max(best, a.speedup)
        return best

    def best_runtime(self, upto: Optional[int] = None,
                     accepted_only: bool = False) -> float:
        s = self.best_speedup(upto, accepted_only)
        return self.t_ref / s if s > 0 else float("inf")

    def gated_best_speedup(self, upto: Optional[int] = None) -> float:
        """Best speedup under gate enforcement: attempts without a label
        are reviewed on the fly, gamed/quarantined attempts score zero."""
        from ..integrity.pipeline import review_attempt

        best = 0.0
        for a in self.attempts[:upto]:
            if not a.label and a.ok:
                a.label = review_attempt(a, self).label
            best = max(best, a.scored_speedup)
        return best

    @property
    def total_tokens(self) -> int:
        return sum(a.tokens for a in self.attempts)

    def tokens_upto(self, upto: int) -> int:
        return sum(a.tokens for a in self.attempts[:upto])

    @property
    def n_attempts(self) -> int:
        return len(self.attempts)

    # ---- serialization -------------------------------------------------
    def to_json(self) -> Dict:
        d = asdict(self)
        for a in d["attempts"]:
            if not math.isfinite(a["runtime_s"]):
                a["runtime_s"] = None
        return d

    @classmethod
    def from_json(cls, d: Dict) -> "RunLog":
        attempts = []
        for a in d["attempts"]:
            a = dict(a)
            if a["runtime_s"] is None:
                a["runtime_s"] = float("inf")
            attempts.append(Attempt(**a))
        d = dict(d)
        d["attempts"] = attempts
        return cls(**d)


def save_runlogs(logs: List[RunLog], path: str) -> None:
    with open(path, "w") as f:
        json.dump([l.to_json() for l in logs], f)


def load_runlogs(path: str) -> List[RunLog]:
    with open(path) as f:
        return [RunLog.from_json(d) for d in json.load(f)]
