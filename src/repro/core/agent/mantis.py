"""MANTIS controllers (paper Sec. 4.2 / 5.5).

Three controller shapes under a matched per-problem attempt budget:

  * MI            — flat Measure-Implement loop (Generate-Compile-Test-Profile
                    per attempt), with either the raw or the DSL
                    representation.
  * in-prompt     — the same flat loop, but the policy follows the MANTIS
                    methodology described "in its prompt": every attempt sees
                    the SOL report, nominates a few hypotheses, ROI-picks one.
  * orchestrated  — explicit multi-phase pipeline with structured artifacts:
                    iterations x (Measure, Analyze, Nominate, Triage,
                    Implement xattempts, Summarize).

Component ablations (Table 3) switch off Analyze / Triage / Summarize /
cross-problem memory.  Gaming inheritance is modeled here: once an exploit
becomes the best-so-far, later attempts tend to carry it forward (Sec. 6.3).
"""

from __future__ import annotations

import math
import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set

from ..problems.base import Problem, Solution
from ..sol.report import SOLReport, make_report
from .costmodel import CostModel, Measurement
from .memory import CrossProblemMemory
from .policies import (DSLPolicy, Hypothesis, P_ADHERE_INPROMPT,
                       P_RAW_INVALID, RawPolicy, SOLGuidedPolicy,
                       TOKENS_INPROMPT_OVERHEAD, TOKENS_NOMINATE,
                       TOKENS_PER_SEGMENT_RAW, TOKENS_RAW,
                       TOKENS_SOL_ANALYSIS, TOKENS_SUMMARIZE, TOKENS_TRIAGE,
                       sample_raw_quality)
from .roi import triage
from .runlog import Attempt, RunLog

P_INHERIT_GAME = 0.5


@dataclass
class AgentConfig:
    representation: str = "dsl"          # raw | dsl
    steering: Optional[str] = None       # None | in_prompt | orchestrated
    capability: str = "mid"              # mini | mid | max
    budget_attempts: int = 40
    iterations: int = 5                  # orchestrated outer passes
    hyps_per_iter: int = 2
    attempts_per_hyp: int = 4
    components: Set[str] = field(
        default_factory=lambda: {"M", "A", "N", "T", "I", "S"})
    cross_problem_memory: bool = True
    seed: int = 0

    @property
    def variant_name(self) -> str:
        rep = "+uPallas" if self.representation == "dsl" else ""
        if self.steering is None:
            return f"MI{rep}"
        return f"{self.steering}{rep}"


class Agent:
    def __init__(self, cfg: AgentConfig, cost_model: Optional[CostModel] = None,
                 memory: Optional[CrossProblemMemory] = None):
        self.cfg = cfg
        self.cost = cost_model or CostModel()
        self.memory = memory if memory is not None else CrossProblemMemory()
        if cfg.steering is not None:
            self.policy = SOLGuidedPolicy(cfg.capability, cfg.seed)
        elif cfg.representation == "dsl":
            self.policy = DSLPolicy(cfg.capability, cfg.seed)
        else:
            self.policy = RawPolicy(cfg.capability, cfg.seed)

    # ------------------------------------------------------------------
    def optimize(self, problem: Problem) -> RunLog:
        base = self.cost.baseline(problem)
        report = make_report(problem.pid, problem.characterization())
        log = RunLog(
            problem_id=problem.pid,
            variant=self.cfg.variant_name,
            capability=self.cfg.capability,
            seed=self.cfg.seed,
            t_ref=base.runtime_s,
            t_sol=report.t_sol,
            t_sol_ceiling=report.t_sol_ceiling,
        )
        import zlib
        key = f"agent|{self.cfg.capability}|{self.cfg.seed}|{problem.pid}"
        rng = random.Random(zlib.crc32(key.encode()))
        state = _SearchState(problem=problem, report=report,
                             t_ref=base.runtime_s)
        if self.cfg.steering == "orchestrated":
            self._run_orchestrated(problem, log, state, rng)
        else:
            self._run_flat(problem, log, state, rng)
        # Summarize: persist cross-problem lessons (legitimate kernels only)
        if "S" in self.cfg.components and self.cfg.cross_problem_memory \
                and state.best_legit_solution is not None:
            legit_speedup = base.runtime_s / state.best_legit_runtime
            if legit_speedup > 1.0:
                cfg_hint = SOLGuidedPolicy(self.cfg.capability)._config_of(
                    state.best_legit_solution, problem)
                self.memory.record(problem, cfg_hint, legit_speedup,
                                   summary=f"best {legit_speedup:.2f}x")
        return log

    # ------------------------------------------------------------------
    def _ctx(self, state: "_SearchState", attempt_idx: int) -> Dict:
        use_sol = self.cfg.steering is not None and \
            "A" in self.cfg.components
        return {
            "attempt": attempt_idx,
            "sol_report": state.report if use_sol else None,
            # hypotheses build on the best *legitimate* kernel — a gaming
            # shortcut has no configuration to improve on
            "best_solution": state.best_legit_solution,
            "best_runtime": state.best_legit_runtime,
            "t_ref": state.t_ref,
            "profile": state.best_profile,
            "memory": (self.memory if (self.cfg.cross_problem_memory and
                                       "S" in self.cfg.components) else None),
        }

    def _tokens_for(self, problem: Problem, extra: int = 0) -> int:
        if self.cfg.representation == "raw":
            base = TOKENS_RAW + TOKENS_PER_SEGMENT_RAW * len(problem.segments)
        else:
            base = self.policy.tokens_per_attempt(problem)
        return base + extra

    def _gate(self, attempt: Attempt, log: RunLog) -> None:
        """Eagerly pass a recorded attempt through the integrity gate: the
        offline pipeline review becomes the attempt's label AND a recorded
        verdict, so a gamed attempt scores zero (``scored_speedup``) the
        moment it lands, not at audit time."""
        from ..integrity.gate import _record_verdict, verdict_from_review
        from ..integrity.pipeline import review_attempt
        from .costmodel import cite_gate_verdict

        r = review_attempt(attempt, log)
        attempt.label = r.label
        v = verdict_from_review(r)
        v.op = f"agent.{log.problem_id}"
        d = v.as_dict()
        d["citation"] = cite_gate_verdict(d)
        attempt.verdict = d
        # ordinary toolchain failures reject without being adversarial, so
        # only quarantines land in the audit metric/trace
        if v.quarantined:
            _record_verdict(v, source="agent")

    def _execute(self, problem: Problem, hyp: Hypothesis,
                 state: "_SearchState", log: RunLog, rng: random.Random,
                 phase: str, extra_tokens: int = 0) -> None:
        """One Generate-Compile-Test-Profile attempt."""
        idx = len(log.attempts)
        tokens = self._tokens_for(problem, extra_tokens)

        # gaming inheritance: once the best is an exploit, carry it forward
        inherited = False
        sol = hyp.solution
        if state.best_is_gaming and not sol.is_gaming() \
                and rng.random() < P_INHERIT_GAME:
            sol = state.best_solution
            inherited = True

        # raw representation: toolchain failures burn the attempt, and the
        # surviving hand-written kernels carry a code-quality penalty the
        # DSL compiler would have eliminated
        toolchain_error = hyp.toolchain_error
        if self.cfg.representation == "raw" and self.cfg.steering is not None:
            if toolchain_error is None and \
                    rng.random() < 0.8 * P_RAW_INVALID[self.cfg.capability]:
                toolchain_error = "low-level implementation error"
            if toolchain_error is None and sol.quality == 1.0 \
                    and not sol.is_gaming() and not sol.is_passthrough():
                import dataclasses as _dc
                sol = _dc.replace(sol, quality=sample_raw_quality(
                    self.cfg.capability, rng))

        if toolchain_error is not None:
            self._gate(log.record(Attempt(
                index=idx, phase=phase, description=hyp.description,
                tokens=tokens, ok=False, runtime_s=float("inf"), speedup=0.0,
                error=toolchain_error, hypothesis=hyp.description)), log)
            return

        m = self.cost.evaluate(problem, sol)
        if not m.ok:
            self._gate(log.record(Attempt(
                index=idx, phase=phase, description=hyp.description,
                tokens=tokens, ok=False, runtime_s=float("inf"), speedup=0.0,
                error=m.error, hypothesis=hyp.description)), log)
            return
        speedup = state.t_ref / m.runtime_s
        flags = sorted(sol.flags)
        if any("bf16" in src or "fp16" in src
               for src in sol.plans.values()):
            # reduced-precision compute on an fp32-specified problem: the
            # LGD labels this a Minor Issue (math approximation), not gaming
            flags.append("reduced_precision")
        self._gate(log.record(Attempt(
            index=idx, phase=phase, description=hyp.description,
            tokens=tokens, ok=True, runtime_s=m.runtime_s, speedup=speedup,
            flags=flags, inherited=inherited,
            hypothesis=hyp.description)), log)
        if m.runtime_s < state.best_runtime:
            state.best_runtime = m.runtime_s
            state.best_speedup = speedup
            state.best_solution = sol
            state.best_is_gaming = sol.is_gaming()
        if not sol.is_gaming() and not sol.is_passthrough() \
                and m.runtime_s < state.best_legit_runtime:
            state.best_legit_runtime = m.runtime_s
            state.best_legit_solution = sol
            state.best_speedup = max(state.best_speedup, speedup)
            state.best_profile = m

    # ------------------------------------------------------------------
    def _run_flat(self, problem: Problem, log: RunLog,
                  state: "_SearchState", rng: random.Random) -> None:
        extra = (TOKENS_INPROMPT_OVERHEAD
                 if self.cfg.steering == "in_prompt" else 0)
        fallback = DSLPolicy(self.cfg.capability, self.cfg.seed + 77)
        while len(log.attempts) < self.cfg.budget_attempts:
            ctx = self._ctx(state, len(log.attempts))
            if self.cfg.steering == "in_prompt":
                # weaker models drift off the in-prompt methodology
                if rng.random() < P_ADHERE_INPROMPT[self.cfg.capability]:
                    hyps = self.policy.nominate(problem, ctx, n=3)
                    gap = state.gap()
                    if "T" in self.cfg.components:
                        hyps = triage(hyps, gap, 1)
                    hyp = hyps[0]
                elif rng.random() < 0.5 and state.best_legit_solution \
                        is not None:
                    # off-script drift: re-submits a variation of the
                    # current best with no new idea (wasted attempt)
                    hyp = Hypothesis(state.best_legit_solution,
                                     "off-script repeat",
                                     tokens=self.policy.tokens_per_attempt(
                                         problem))
                else:
                    hyp = fallback.propose(problem, ctx)
            else:
                hyp = self.policy.propose(problem, ctx)
            self._execute(problem, hyp, state, log, rng, "implement", extra)

    def _run_orchestrated(self, problem: Problem, log: RunLog,
                          state: "_SearchState", rng: random.Random) -> None:
        cfg = self.cfg
        for it in range(cfg.iterations):
            if len(log.attempts) >= cfg.budget_attempts:
                break
            phase_tokens = 0
            # Measure + Analyze (structured artifacts)
            if "A" in cfg.components:
                phase_tokens += TOKENS_SOL_ANALYSIS if it == 0 else 150
            # Nominate
            ctx = self._ctx(state, len(log.attempts))
            hyps = self.policy.nominate(problem, ctx,
                                        n=2 * cfg.hyps_per_iter)
            phase_tokens += TOKENS_NOMINATE
            # Triage
            gap = state.gap()
            if "T" in cfg.components:
                hyps = triage(hyps, gap, cfg.hyps_per_iter)
                phase_tokens += TOKENS_TRIAGE
            else:
                rng.shuffle(hyps)
                hyps = hyps[:cfg.hyps_per_iter]
            # Implement: fixed attempt budget per hypothesis
            for h_i, hyp in enumerate(hyps):
                variants = [hyp]
                # local jitter around the hypothesis for the extra attempts
                for v in range(cfg.attempts_per_hyp - 1):
                    variants.append(self._jitter(problem, hyp, rng, v))
                for v, hv in enumerate(variants):
                    if len(log.attempts) >= cfg.budget_attempts:
                        break
                    extra = phase_tokens if (h_i == 0 and v == 0) else 0
                    self._execute(problem, hv, state, log, rng,
                                  f"iter{it}", extra)
            # Summarize
            if "S" in cfg.components:
                # token cost only; lessons persisted at the end of optimize()
                if log.attempts:
                    log.attempts[-1].tokens += TOKENS_SUMMARIZE

    def _jitter(self, problem: Problem, hyp: Hypothesis,
                rng: random.Random, v: int) -> Hypothesis:
        """Local exploration inside a hypothesis' attempt budget."""
        if not isinstance(self.policy, SOLGuidedPolicy) \
                or hyp.solution.is_gaming() or hyp.solution.is_passthrough():
            return hyp
        cfg = self.policy._config_of(hyp.solution, problem)
        which = rng.choice(["stages", "tile_k", "tile_m"])
        if which == "stages":
            cfg["stages"] = max(1, min(4, cfg["stages"] + rng.choice([-1, 1])))
        elif which == "tile_k" and cfg["tiles"]:
            cfg["tiles"] = {k: (t[0], t[1],
                                max(128, min(1024, t[2] * rng.choice([1, 2]))))
                            for k, t in cfg["tiles"].items()}
        elif cfg["tiles"]:
            cfg["tiles"] = {k: (max(64, min(512, t[0] * rng.choice([1, 2]))),
                                t[1], t[2])
                            for k, t in cfg["tiles"].items()}
        sol = self.policy._rebuild(problem, cfg)
        return Hypothesis(sol, hyp.description + f" (variant {v + 1})",
                          est_speedup=hyp.est_speedup,
                          risk_impl=hyp.risk_impl, risk_perf=hyp.risk_perf,
                          tokens=hyp.tokens)


@dataclass
class _SearchState:
    problem: Problem
    report: SOLReport
    t_ref: float
    best_runtime: float = float("inf")
    best_speedup: float = 0.0
    best_solution: Optional[Solution] = None
    best_legit_runtime: float = float("inf")
    best_legit_solution: Optional[Solution] = None
    best_profile: Optional[Measurement] = None
    best_is_gaming: bool = False

    def gap(self) -> float:
        if not math.isfinite(self.best_legit_runtime):
            return 100.0
        return self.best_legit_runtime / max(self.report.t_sol, 1e-12)
