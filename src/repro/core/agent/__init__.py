"""MANTIS agent stack: policies, cost model, controllers, memory, logs."""

from .costmodel import CostModel, Measurement, SegmentCost
from .mantis import Agent, AgentConfig
from .memory import CrossProblemMemory
from .policies import (BasePolicy, DSLPolicy, Hypothesis, RawPolicy,
                       SOLGuidedPolicy, make_policy, PRICE_PER_MTOK,
                       CAPABILITIES)
from .roi import roi, triage
from .runlog import Attempt, RunLog, load_runlogs, save_runlogs
from .variants import ABLATIONS, VARIANTS, run_variant, best_steering_variant

__all__ = [
    "CostModel", "Measurement", "SegmentCost", "Agent", "AgentConfig",
    "CrossProblemMemory", "BasePolicy", "DSLPolicy", "Hypothesis",
    "RawPolicy", "SOLGuidedPolicy", "make_policy", "PRICE_PER_MTOK",
    "CAPABILITIES", "roi", "triage", "Attempt", "RunLog", "load_runlogs",
    "save_runlogs", "ABLATIONS", "VARIANTS", "run_variant",
    "best_steering_variant",
]
