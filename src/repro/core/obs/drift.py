"""Predicted-vs-measured drift detection over SOL-attributed observations.

The paper's discipline is that every optimization decision is justified by
a first-principles prediction (FLOPs, HBM bytes, wire bytes, a roofline
bound) and then checked against measurement — the sweep benchmarks all
assert the two agree within 20%.  The :class:`DriftDetector` makes that
check continuous: every closed SOL-attributed span (or explicit
``observe`` call) folds into a per-op windowed ratio
``measured / predicted``, and *sustained* drift beyond the same 20%
tolerance raises a :class:`DriftEvent`.

Two kinds of predictions, two drift directions:

* **bounds** (``calibrated=False``, the default) — a speed-of-light
  number.  Measurement is expected to sit *above* the bound (often far
  above on CPU interpret mode); the only implausible direction is
  measured < (1 - tol) * bound, which means the measurement beats physics
  — the serving-side analogue of the integrity pipeline's SOL-ceiling
  gaming detector (``direction="below_bound"``).
* **calibrated models** (``calibrated=True``) — an estimate that already
  includes an achieved-efficiency factor or an exact analytic count
  (bytes, dispatches).  Drift in *either* direction beyond the tolerance
  marks the model stale (``direction="above_model"`` / ``"below_bound"``).

``core/integrity/pipeline.py:review_drift`` maps drift events onto the
integrity labels, and ``core/agent/costmodel.py:cite_drift_report`` cites
the report in agent hypothesis notes.
"""

from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional

DEFAULT_TOLERANCE = 0.20      # the sweeps' shared predicted-vs-measured band
DEFAULT_WINDOW = 16
DEFAULT_MIN_SAMPLES = 3


@dataclass
class DriftEvent:
    """One op's transition into sustained drift."""

    op: str
    direction: str            # below_bound | above_model
    mean_ratio: float         # windowed mean of measured / predicted
    n: int                    # samples in the window
    unit: str = "s"
    calibrated: bool = False
    predicted: float = 0.0    # last observation's prediction
    measured: float = 0.0     # last observation's measurement

    def as_dict(self) -> Dict[str, object]:
        return {
            "op": self.op, "direction": self.direction,
            "mean_ratio": self.mean_ratio, "n": self.n, "unit": self.unit,
            "calibrated": self.calibrated, "predicted": self.predicted,
            "measured": self.measured,
        }


@dataclass
class _OpState:
    ratios: Deque[float]
    unit: str = "s"
    calibrated: bool = False
    predicted: float = 0.0
    measured: float = 0.0
    total: int = 0
    drifting: bool = False
    direction: str = ""


class DriftDetector:
    """Folds predicted-vs-measured pairs into per-op drift verdicts.

    Thread-safe; zero dependencies; cheap enough to stay always-on (one
    deque append + a windowed mean per observation).  ``on_event`` fires
    once per op per *transition into* drift (not per drifting sample), so
    consumers see incidents, not noise.
    """

    def __init__(self, *, tolerance: float = DEFAULT_TOLERANCE,
                 window: int = DEFAULT_WINDOW,
                 min_samples: int = DEFAULT_MIN_SAMPLES,
                 on_event: Optional[Callable[[DriftEvent], None]] = None):
        self.tolerance = float(tolerance)
        self.window = int(window)
        self.min_samples = int(min_samples)
        self.on_event = on_event
        self.events: List[DriftEvent] = []
        self._ops: Dict[str, _OpState] = {}
        self._listeners: List[Callable[[DriftEvent], None]] = []
        self._lock = threading.Lock()

    def add_listener(self, fn: Callable[[DriftEvent], None]) -> None:
        """Subscribe an additional event consumer (idempotent).  Listeners
        fire after ``on_event``, outside the lock, exceptions swallowed —
        the integrity gate hangs its ``below_bound`` quarantine here."""
        with self._lock:
            if fn not in self._listeners:
                self._listeners.append(fn)

    # ------------------------------------------------------------------
    def observe(self, op: str, predicted: float, measured: float, *,
                unit: str = "s",
                calibrated: bool = False) -> Optional[DriftEvent]:
        """Record one pair; returns a DriftEvent on transition into drift."""
        if predicted is None or measured is None:
            return None
        predicted = float(predicted)
        measured = float(measured)
        if predicted <= 0.0 or measured < 0.0:
            return None
        ratio = measured / predicted
        with self._lock:
            st = self._ops.get(op)
            if st is None:
                st = self._ops[op] = _OpState(
                    ratios=deque(maxlen=self.window))
            st.ratios.append(ratio)
            st.unit = unit
            st.calibrated = calibrated
            st.predicted = predicted
            st.measured = measured
            st.total += 1
            mean = sum(st.ratios) / len(st.ratios)
            below = mean < 1.0 - self.tolerance
            above = calibrated and mean > 1.0 + self.tolerance
            drifting = len(st.ratios) >= self.min_samples and (below or above)
            direction = "below_bound" if below else (
                "above_model" if above else "")
            transitioned = drifting and not st.drifting
            st.drifting = drifting
            st.direction = direction
            event = None
            if transitioned:
                event = DriftEvent(op=op, direction=direction,
                                   mean_ratio=mean, n=len(st.ratios),
                                   unit=unit, calibrated=calibrated,
                                   predicted=predicted, measured=measured)
                self.events.append(event)
        self._publish_gauge(op, mean)
        if event is None:
            return None
        # fire outside the lock: the callback may log / trace / re-enter
        if self.on_event is not None:
            try:
                self.on_event(event)
            except Exception:
                pass
        with self._lock:
            listeners = list(self._listeners)
        for fn in listeners:
            try:
                fn(event)
            except Exception:
                pass
        return event

    def _publish_gauge(self, op: str, mean: float) -> None:
        """Mirror the windowed ratio into the default metrics registry so
        ``/metrics`` exports ``repro_sol_drift_ratio{op=...}``."""
        try:
            from .metrics import default_registry

            default_registry().gauge(
                "repro_sol_drift_ratio",
                "windowed mean of measured / SOL-predicted per op",
                labels=("op",)).set(mean, op=op)
        except Exception:
            pass

    # ------------------------------------------------------------------
    def report(self) -> Dict[str, Dict[str, object]]:
        """Per-op summary: {op: {n, mean_ratio, drifting, direction, ...}}."""
        out: Dict[str, Dict[str, object]] = {}
        with self._lock:
            for op, st in sorted(self._ops.items()):
                mean = (sum(st.ratios) / len(st.ratios)) if st.ratios \
                    else float("nan")
                out[op] = {
                    "n": st.total,
                    "window_n": len(st.ratios),
                    "mean_ratio": mean,
                    "drifting": st.drifting,
                    "direction": st.direction,
                    "unit": st.unit,
                    "calibrated": st.calibrated,
                    "predicted": st.predicted,
                    "measured": st.measured,
                }
        return out

    def drifting_ops(self) -> List[str]:
        with self._lock:
            return sorted(op for op, st in self._ops.items() if st.drifting)

    def table(self) -> str:
        """Markdown drift table (GITHUB_STEP_SUMMARY / launcher output)."""
        rows = ["| op | n | measured/predicted | unit | calibrated | "
                "drift |", "|---|---|---|---|---|---|"]
        for op, r in self.report().items():
            flag = r["direction"] if r["drifting"] else "ok"
            rows.append(
                f"| {op} | {r['n']} | {r['mean_ratio']:.3g} | {r['unit']} "
                f"| {'yes' if r['calibrated'] else 'no'} | {flag} |")
        return "\n".join(rows)

    def reset(self) -> None:
        with self._lock:
            self._ops.clear()
            self.events.clear()
