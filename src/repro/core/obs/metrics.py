"""Counters / gauges / histograms with Prometheus text exposition.

Zero-dependency, thread-safe, label-aware.  The gateway publishes the
default registry at ``GET /metrics`` in Prometheus text format
(version 0.0.4); ``MetricsRegistry.snapshot()`` is the JSON twin used by
``GET /metrics.json``.

Metric types follow Prometheus semantics:

* ``Counter``   — monotonically increasing (``inc``),
* ``Gauge``     — set to arbitrary values (``set`` / ``inc``),
* ``Histogram`` — cumulative ``le`` buckets plus ``_sum`` / ``_count``.

Instruments are get-or-created through the registry so call sites can be
written as one-liners::

    default_registry().counter("repro_requests_total",
                               "requests admitted", labels=("slo",))\\
                      .inc(slo="interactive")
"""

from __future__ import annotations

import math
import threading
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

DEFAULT_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
                   0.5, 1.0, 2.5, 5.0, 10.0, float("inf"))


def _fmt_value(v: float) -> str:
    if math.isnan(v):
        return "NaN"
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def _escape(v: object) -> str:
    return str(v).replace("\\", r"\\").replace('"', r'\"') \
        .replace("\n", r"\n")


def _label_str(names: Sequence[str], values: Sequence[object],
               extra: Sequence[Tuple[str, str]] = ()) -> str:
    pairs = [f'{n}="{_escape(v)}"' for n, v in zip(names, values)]
    pairs += [f'{n}="{v}"' for n, v in extra]
    return "{" + ",".join(pairs) + "}" if pairs else ""


class _Metric:
    kind = "untyped"

    def __init__(self, name: str, help: str = "",
                 labels: Sequence[str] = ()):
        self.name = name
        self.help = help
        self.labels = tuple(labels)
        self._lock = threading.Lock()

    def _key(self, label_kw: Dict[str, object]) -> Tuple[object, ...]:
        extra = set(label_kw) - set(self.labels)
        if extra:
            raise KeyError(
                f"{self.name}: unknown labels {sorted(extra)} "
                f"(declared: {list(self.labels)})")
        return tuple(label_kw.get(n, "") for n in self.labels)


class Counter(_Metric):
    kind = "counter"

    def __init__(self, name: str, help: str = "",
                 labels: Sequence[str] = ()):
        super().__init__(name, help, labels)
        self._values: Dict[Tuple[object, ...], float] = {}

    def inc(self, amount: float = 1.0, **labels) -> None:
        if amount < 0:
            raise ValueError(f"{self.name}: counters only go up")
        key = self._key(labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + amount

    def value(self, **labels) -> float:
        return self._values.get(self._key(labels), 0.0)

    def _render(self) -> List[str]:
        with self._lock:
            return [f"{self.name}{_label_str(self.labels, k)} "
                    f"{_fmt_value(v)}"
                    for k, v in sorted(self._values.items(), key=str)]

    def _snapshot(self) -> object:
        with self._lock:
            if not self.labels:
                return self._values.get((), 0.0)
            return [{"labels": dict(zip(self.labels, k)), "value": v}
                    for k, v in sorted(self._values.items(), key=str)]


class Gauge(Counter):
    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        key = self._key(labels)
        with self._lock:
            self._values[key] = float(value)

    def inc(self, amount: float = 1.0, **labels) -> None:
        key = self._key(labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + amount


class Histogram(_Metric):
    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 labels: Sequence[str] = (),
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        super().__init__(name, help, labels)
        bs = sorted(float(b) for b in buckets)
        if not bs or not math.isinf(bs[-1]):
            bs.append(float("inf"))
        self.buckets = tuple(bs)
        # per label-key: [bucket counts..., sum, count]
        self._series: Dict[Tuple[object, ...], List[float]] = {}

    def observe(self, value: float, **labels) -> None:
        value = float(value)
        if math.isnan(value):
            return
        key = self._key(labels)
        with self._lock:
            s = self._series.get(key)
            if s is None:
                s = self._series[key] = [0.0] * (len(self.buckets) + 2)
            for i, b in enumerate(self.buckets):
                if value <= b:
                    s[i] += 1
            s[-2] += value
            s[-1] += 1

    def count(self, **labels) -> int:
        s = self._series.get(self._key(labels))
        return int(s[-1]) if s else 0

    def _render(self) -> List[str]:
        lines: List[str] = []
        with self._lock:
            for key, s in sorted(self._series.items(), key=str):
                for i, b in enumerate(self.buckets):
                    le = "+Inf" if math.isinf(b) else _fmt_value(b)
                    lines.append(
                        f"{self.name}_bucket"
                        f"{_label_str(self.labels, key, (('le', le),))} "
                        f"{_fmt_value(s[i])}")
                lines.append(f"{self.name}_sum"
                             f"{_label_str(self.labels, key)} "
                             f"{_fmt_value(s[-2])}")
                lines.append(f"{self.name}_count"
                             f"{_label_str(self.labels, key)} "
                             f"{_fmt_value(s[-1])}")
        return lines

    def _snapshot(self) -> object:
        with self._lock:
            out = []
            for key, s in sorted(self._series.items(), key=str):
                out.append({
                    "labels": dict(zip(self.labels, key)),
                    "count": s[-1], "sum": s[-2],
                    "buckets": {("+Inf" if math.isinf(b) else b): s[i]
                                for i, b in enumerate(self.buckets)},
                })
            return out


class MetricsRegistry:
    """Named metrics with get-or-create accessors and text exposition."""

    def __init__(self):
        self._metrics: Dict[str, _Metric] = {}
        self._lock = threading.Lock()

    def _get(self, cls, name: str, help: str, labels: Sequence[str],
             **kw) -> _Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, help, labels, **kw)
            elif not isinstance(m, cls) or (cls is Counter
                                            and isinstance(m, Gauge)):
                raise TypeError(
                    f"metric {name} already registered as {m.kind}")
            return m

    def counter(self, name: str, help: str = "",
                labels: Sequence[str] = ()) -> Counter:
        return self._get(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Sequence[str] = ()) -> Gauge:
        return self._get(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  labels: Sequence[str] = (),
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help, labels, buckets=buckets)

    def get(self, name: str) -> Optional[_Metric]:
        return self._metrics.get(name)

    # ------------------------------------------------------------------
    def render_prometheus(self) -> str:
        """Prometheus text exposition (format version 0.0.4)."""
        lines: List[str] = []
        with self._lock:
            metrics = list(self._metrics.values())
        for m in metrics:
            if m.help:
                lines.append(f"# HELP {m.name} {_escape(m.help)}")
            lines.append(f"# TYPE {m.name} {m.kind}")
            lines.extend(m._render())
        return "\n".join(lines) + "\n"

    def snapshot(self) -> Dict[str, object]:
        """JSON-friendly dump (the ``/metrics.json`` twin)."""
        with self._lock:
            metrics = list(self._metrics.values())
        return {m.name: {"type": m.kind, "help": m.help,
                         "values": m._snapshot()} for m in metrics}

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()


_DEFAULT = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """The process-wide registry the gateway publishes at ``/metrics``."""
    return _DEFAULT
