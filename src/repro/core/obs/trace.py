"""SOL-attributed tracing: spans, point events, ring buffer, JSONL sink,
Chrome/Perfetto export.

One process-wide :class:`Tracer` (see :func:`configure` /
:func:`get_tracer`) that every subsystem reports into.  Tracing is
opt-in: until configured — via ``configure(path)``, ``REPRO_TRACE=path``,
``launch/serve.py --trace`` or ``start_gateway(trace=...)`` — the global
tracer is the :data:`NULL_TRACER`, whose ``span`` / ``event`` calls are
single attribute lookups returning a shared no-op span, so instrumented
hot paths pay nanoseconds and format no strings.

Span schema (see ``core/obs/__init__`` for field-by-field docs)::

    with get_tracer().span("tune.trial", cat="tune", op="gemm",
                           sol={"t_sol_s": 1e-4, "predicted": 2e-4,
                                "bound": "memory"}) as sp:
        ...
        sp.set(median_s=measured)

On close, a span with ``sol.t_sol_s`` gets ``sol_efficiency =
t_sol_s / duration`` (achieved fraction of speed-of-light), and a span
whose ``sol`` carries ``predicted`` (plus optionally ``measured``,
defaulting to the span duration) is folded into the process
:class:`~repro.core.obs.drift.DriftDetector`.
"""

from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from .drift import DriftDetector
from .serialize import to_jsonable

DEFAULT_RING = 65536


@dataclass
class Span:
    """One closed span (``ph="X"``) or point event (``ph="i"``)."""

    name: str
    cat: str
    ts: float                 # seconds since the tracer's epoch
    dur: float = 0.0          # seconds (0 for point events)
    ph: str = "X"
    tid: int = 0
    attrs: Dict[str, Any] = field(default_factory=dict)
    sol: Optional[Dict[str, Any]] = None
    sol_efficiency: Optional[float] = None

    def as_dict(self) -> Dict[str, Any]:
        d = {"name": self.name, "cat": self.cat, "ph": self.ph,
             "ts_s": self.ts, "dur_s": self.dur, "tid": self.tid,
             "attrs": self.attrs}
        if self.sol is not None:
            d["sol"] = self.sol
        if self.sol_efficiency is not None:
            d["sol_efficiency"] = self.sol_efficiency
        return d

    def chrome_event(self, pid: int) -> Dict[str, Any]:
        args = dict(self.attrs)
        if self.sol is not None:
            args["sol"] = self.sol
        if self.sol_efficiency is not None:
            args["sol_efficiency"] = self.sol_efficiency
        ev = {"name": self.name, "cat": self.cat, "ph": self.ph,
              "pid": pid, "tid": self.tid,
              "ts": self.ts * 1e6, "args": to_jsonable(args)}
        if self.ph == "X":
            ev["dur"] = self.dur * 1e6
        else:
            ev["s"] = "t"                 # instant event, thread scope
        return ev


class _NullSpan:
    """Shared no-op span: zero allocation, zero formatting."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> "_NullSpan":
        return self


NULL_SPAN = _NullSpan()


class _LiveSpan:
    __slots__ = ("_tracer", "name", "cat", "attrs", "sol", "_t0")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 sol: Optional[Dict[str, Any]], attrs: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.sol = sol
        self.attrs = attrs
        self._t0 = 0.0

    def __enter__(self) -> "_LiveSpan":
        self._t0 = self._tracer.now()
        return self

    def set(self, **attrs) -> "_LiveSpan":
        self.attrs.update(attrs)
        return self

    def __exit__(self, *exc) -> bool:
        if exc and exc[0] is not None:
            self.attrs.setdefault("error", str(exc[1]))
        end = self._tracer.now()
        self._tracer._record(Span(
            name=self.name, cat=self.cat, ts=self._t0,
            dur=max(end - self._t0, 0.0), ph="X",
            tid=threading.get_ident() & 0xFFFF,
            attrs=self.attrs, sol=self.sol))
        return False


class Tracer:
    """Thread-safe span/event recorder with an in-memory ring buffer, an
    optional JSONL sink, and Chrome-trace export."""

    enabled = True

    def __init__(self, *, ring: int = DEFAULT_RING,
                 jsonl_path: Optional[str] = None,
                 drift: Optional[DriftDetector] = None,
                 clock=time.perf_counter):
        self._clock = clock
        self._epoch = clock()
        self._lock = threading.Lock()
        self._ring: deque = deque(maxlen=ring)
        self._pid = os.getpid()
        self.drift = drift
        self.dropped = 0
        self._jsonl_path = jsonl_path
        self._jsonl = None
        if jsonl_path:
            d = os.path.dirname(jsonl_path)
            if d:
                os.makedirs(d, exist_ok=True)
            self._jsonl = open(jsonl_path, "a")

    # ------------------------------------------------------------------
    def now(self) -> float:
        """Seconds since this tracer's epoch (span timestamps' clock)."""
        return self._clock() - self._epoch

    def span(self, name: str, cat: str = "repro",
             sol: Optional[Dict[str, Any]] = None, **attrs) -> _LiveSpan:
        """Context-manager span; closes (and records) on ``__exit__``."""
        return _LiveSpan(self, name, cat, sol, attrs)

    def event(self, name: str, cat: str = "repro",
              sol: Optional[Dict[str, Any]] = None, **attrs) -> None:
        """Point event (``ph="i"``)."""
        self._record(Span(name=name, cat=cat, ts=self.now(), ph="i",
                          tid=threading.get_ident() & 0xFFFF,
                          attrs=attrs, sol=sol))

    def complete(self, name: str, *, dur_s: float, cat: str = "repro",
                 sol: Optional[Dict[str, Any]] = None, **attrs) -> None:
        """Record a span that ends *now* and lasted ``dur_s`` — for paths
        (async handlers, pre-timed sections) where a ``with`` block can't
        bracket the work."""
        end = self.now()
        self._record(Span(name=name, cat=cat, ts=max(end - dur_s, 0.0),
                          dur=max(dur_s, 0.0), ph="X",
                          tid=threading.get_ident() & 0xFFFF,
                          attrs=attrs, sol=sol))

    # ------------------------------------------------------------------
    def _record(self, span: Span) -> None:
        sol = span.sol
        if sol is not None:
            t_sol = sol.get("t_sol_s")
            if t_sol and span.dur > 0:
                span.sol_efficiency = float(t_sol) / span.dur
            pred = sol.get("predicted")
            if pred is not None and self.drift is not None:
                measured = sol.get("measured")
                if measured is None and span.ph == "X":
                    measured = span.dur
                if measured is not None:
                    self.drift.observe(
                        sol.get("op", span.name), pred, measured,
                        unit=sol.get("unit", "s"),
                        calibrated=bool(sol.get("calibrated", False)))
        with self._lock:
            if len(self._ring) == self._ring.maxlen:
                self.dropped += 1
            self._ring.append(span)
            if self._jsonl is not None:
                self._jsonl.write(
                    json.dumps(to_jsonable(span.as_dict())) + "\n")

    # ------------------------------------------------------------------
    def spans(self) -> List[Span]:
        with self._lock:
            return list(self._ring)

    def categories(self) -> List[str]:
        """Distinct span categories seen (subsystem coverage check)."""
        return sorted({s.cat for s in self.spans()})

    def export_chrome(self, path: str) -> str:
        """Write a Chrome trace-event file (Perfetto / chrome://tracing)."""
        events = [s.chrome_event(self._pid) for s in self.spans()]
        payload = {"traceEvents": events, "displayTimeUnit": "ms",
                   "otherData": {"dropped_spans": self.dropped}}
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(payload, f)
        return path

    def flush(self) -> None:
        with self._lock:
            if self._jsonl is not None:
                self._jsonl.flush()

    def close(self) -> None:
        with self._lock:
            if self._jsonl is not None:
                self._jsonl.close()
                self._jsonl = None


class NullTracer:
    """Disabled tracer: every call is a no-op, no strings are built."""

    enabled = False
    drift = None
    dropped = 0

    def now(self) -> float:
        return 0.0

    def span(self, name, cat="repro", sol=None, **attrs) -> _NullSpan:
        return NULL_SPAN

    def event(self, name, cat="repro", sol=None, **attrs) -> None:
        pass

    def complete(self, name, *, dur_s, cat="repro", sol=None,
                 **attrs) -> None:
        pass

    def spans(self) -> List[Span]:
        return []

    def categories(self) -> List[str]:
        return []

    def export_chrome(self, path: str) -> str:
        raise RuntimeError("tracing is disabled (configure() first)")

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass


NULL_TRACER = NullTracer()

# process-wide state: one always-available drift detector (cheap enough to
# stay on even without tracing) and the opt-in tracer
_DRIFT = DriftDetector()
_TRACER: object = NULL_TRACER
_ENV_CHECKED = False


def default_drift() -> DriftDetector:
    """The process drift detector (always on; the tracer feeds it too)."""
    return _DRIFT


# back-compat alias used by instrumentation call sites
def get_drift() -> DriftDetector:
    return _DRIFT


def get_tracer():
    """The process tracer; the NULL_TRACER until tracing is configured.
    ``REPRO_TRACE=path`` configures it on first use."""
    global _ENV_CHECKED
    if _TRACER is NULL_TRACER and not _ENV_CHECKED:
        _ENV_CHECKED = True
        path = os.environ.get("REPRO_TRACE")
        if path:
            configure(path)
    return _TRACER


def configure(path: Optional[str] = None, *, ring: int = DEFAULT_RING,
              drift: Optional[DriftDetector] = None,
              export_at_exit: Optional[bool] = None) -> Tracer:
    """Enable tracing process-wide and return the tracer.

    ``path`` ending in ``.jsonl`` streams every closed span as one JSON
    line (durable even on crash); any other path buffers spans in the
    ring and exports a Chrome trace there at interpreter exit (or call
    ``export_chrome`` yourself, as ``launch/serve.py --trace`` does).
    """
    global _TRACER
    jsonl = path if (path and path.endswith(".jsonl")) else None
    tracer = Tracer(ring=ring, jsonl_path=jsonl,
                    drift=drift if drift is not None else _DRIFT)
    if export_at_exit is None:
        export_at_exit = bool(path) and jsonl is None
    if export_at_exit and path:
        import atexit

        atexit.register(lambda: _TRACER is tracer
                        and tracer.export_chrome(path))
    _TRACER = tracer
    return tracer


def disable() -> None:
    """Back to the no-op tracer (tests; flushes/closes the old sink)."""
    global _TRACER
    old = _TRACER
    _TRACER = NULL_TRACER
    if isinstance(old, Tracer):
        old.close()
