"""SOL-attributed flight recorder: tracing, metrics, drift detection.

Zero-dependency observability that every layer of the repo reports into:
DSL compile (``cat="compile"``), autotune trials (``cat="tune"``), SOL
reports (``cat="sol"``), serve engine steps (``cat="serve"``), gateway /
router lifecycle (``cat="gateway"``), and agent attempts
(``cat="agent"``).  Three pieces:

* :class:`Tracer` (``trace.py``) — thread-safe context-manager spans and
  point events into a ring buffer, optional JSONL sink, and
  Chrome/Perfetto export via :meth:`Tracer.export_chrome`.
* :class:`MetricsRegistry` (``metrics.py``) — counters / gauges /
  histograms with Prometheus text exposition; the gateway publishes the
  :func:`default_registry` at ``GET /metrics`` (JSON twin at
  ``/metrics.json``).
* :class:`DriftDetector` (``drift.py``) — folds SOL-attributed spans
  into per-op ``measured / predicted`` ratios and flags sustained >20%
  drift, the same band every sweep benchmark asserts.

Span schema
-----------

Every span / event serializes (JSONL ``as_dict`` and Chrome ``args``)
with these fields:

====================  ====================================================
``name``              dotted event name, e.g. ``engine.step``,
                      ``tune.trial``, ``compile.dsl``, ``router.ticket``
``cat``               subsystem: ``compile`` | ``tune`` | ``sol`` |
                      ``serve`` | ``gateway`` | ``agent`` | ``bench``
``ph``                ``"X"`` complete span, ``"i"`` instant event
``ts_s`` / ``dur_s``  start (seconds since tracer epoch) and duration;
                      Chrome export converts both to microseconds
``tid``               originating thread id (folded to 16 bits)
``attrs``             free-form key/value payload (raw values, never
                      pre-formatted strings)
``sol``               optional SOL attribution — see below
``sol_efficiency``    ``sol.t_sol_s / dur_s``, filled at span close:
                      achieved fraction of speed-of-light
====================  ====================================================

SOL attribution fields (the ``sol`` dict)
-----------------------------------------

``flops``             predicted floating-point work for the span
``hbm_bytes``         predicted HBM traffic
``wire_bytes``        predicted interconnect traffic (sharded runs)
``bound``             roofline verdict: ``compute`` | ``memory`` |
                      ``collective``
``t_sol_s``           speed-of-light time bound for the span's work
``predicted``         the prediction to hold measurement against; its
                      presence opts the span into the
                      :class:`DriftDetector`
``measured``          the measurement (defaults to the span's duration)
``op``                drift-accounting key (defaults to the span name)
``unit``              unit of predicted/measured (default ``"s"``)
``calibrated``        ``False`` (default): ``predicted`` is a physical
                      *bound*; only measured < (1 - tol) x predicted —
                      beating physics — counts as drift
                      (``below_bound``).  ``True``: ``predicted`` is a
                      calibrated estimate or exact analytic count; drift
                      in either direction flags the model stale
                      (``above_model`` / ``below_bound``).

Tracing is opt-in (``REPRO_TRACE=path``, ``launch/serve.py --trace``,
``start_gateway(trace=...)``) and near-zero-cost when disabled: the
global tracer is a shared no-op until :func:`configure` runs.
"""

from .drift import DriftDetector, DriftEvent
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      default_registry)
from .serialize import to_jsonable
from .trace import (NULL_TRACER, NullTracer, Span, Tracer, configure,
                    default_drift, disable, get_drift, get_tracer)

__all__ = [
    "Counter",
    "DriftDetector",
    "DriftEvent",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NULL_TRACER",
    "NullTracer",
    "Span",
    "Tracer",
    "configure",
    "default_drift",
    "default_registry",
    "disable",
    "get_drift",
    "get_tracer",
    "to_jsonable",
]
