"""The one JSON serializer shared by every exposition endpoint.

Replaces the gateway's former ``json.loads(json.dumps(x, default=str))``
round-trip: one recursive pass that maps the repo's telemetry payloads
onto strict JSON values.  Documented conversions:

* ``nan`` / ``inf`` floats -> ``None`` (strict JSON has no NaN literal;
  telemetry percentiles are nan when no request finished),
* numpy scalars / 0-d arrays -> native Python via ``.item()``,
* numpy arrays / tuples / sets -> lists,
* dataclasses -> field dicts, Enums -> their ``value``,
* dict keys -> strings,
* anything else unrecognized -> ``str(obj)``.
"""

from __future__ import annotations

import dataclasses
import math
from enum import Enum
from typing import Any


def to_jsonable(obj: Any) -> Any:
    """Recursively convert ``obj`` into strict-JSON-safe values."""
    if obj is None or isinstance(obj, (bool, int, str)):
        return obj
    if isinstance(obj, float):
        return None if (math.isnan(obj) or math.isinf(obj)) else obj
    if isinstance(obj, Enum):
        return to_jsonable(obj.value)
    if isinstance(obj, dict):
        return {str(k): to_jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple, set, frozenset)):
        return [to_jsonable(v) for v in obj]
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return {f.name: to_jsonable(getattr(obj, f.name))
                for f in dataclasses.fields(obj)}
    # numpy scalars and 0-d arrays expose .item(); arrays expose .tolist()
    item = getattr(obj, "item", None)
    if callable(item) and getattr(obj, "shape", None) in ((), None):
        try:
            return to_jsonable(item())
        except Exception:
            pass
    tolist = getattr(obj, "tolist", None)
    if callable(tolist):
        try:
            return to_jsonable(tolist())
        except Exception:
            pass
    return str(obj)
