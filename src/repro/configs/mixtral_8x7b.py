"""mixtral-8x7b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, MoE 8e top-2, sliding-window attention. [arXiv:2401.04088]

SWA makes decode cost independent of total context -> long_500k runs."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=32000,
    num_experts=8, num_experts_per_tok=2,
    sliding_window=4096, rope_theta=1e6, max_position=131072,
    notes="8-expert top-2 MoE with 4k sliding window",
)
