"""command-r-plus-104b [dense] — 64L d_model=12288 96H (GQA kv=8)
d_ff=33792 vocab=256000, no bias. [hf:CohereForAI/c4ai-command-r-plus]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b", family="dense",
    num_layers=64, d_model=12288, num_heads=96, num_kv_heads=8,
    head_dim=128, d_ff=33792, vocab_size=256000,
    rope_theta=75e6, max_position=131072, tie_embeddings=True,
    notes="largest dense arch in the pool; FSDP+TP stress test",
)
