"""qwen1.5-4b [dense] — 40L d_model=2560 20H (GQA kv=20) d_ff=6912
vocab=151936, QKV bias. [hf:Qwen/Qwen1.5-4B]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b", family="dense",
    num_layers=40, d_model=2560, num_heads=20, num_kv_heads=20,
    d_ff=6912, vocab_size=151936,
    qkv_bias=True, rope_theta=5e6, max_position=32768,
    notes="MHA (kv == q heads) with QKV bias",
)
