"""mistral-nemo-12b [dense] — 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072, 128k ctx. [hf:mistralai/Mistral-Nemo-Base-2407]

Full attention at 128k context: quadratic, so long_500k is skipped
(DESIGN.md SS Arch-applicability)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b", family="dense",
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8,
    head_dim=128,                      # Nemo uses 128 (not d_model/heads=160)
    d_ff=14336, vocab_size=131072,
    rope_theta=1e6, max_position=131072,
    notes="128k-context dense GQA model",
)
