"""Assigned architecture registry: 10 configs x 4 input shapes."""

from typing import Dict, List

from .base import ModelConfig, ShapeConfig, SHAPES, SMOKE_SHAPES, pad_vocab
from .mistral_nemo_12b import CONFIG as MISTRAL_NEMO_12B
from .qwen15_4b import CONFIG as QWEN15_4B
from .command_r_plus_104b import CONFIG as COMMAND_R_PLUS_104B
from .qwen2_05b import CONFIG as QWEN2_05B
from .granite_moe_1b import CONFIG as GRANITE_MOE_1B
from .mixtral_8x7b import CONFIG as MIXTRAL_8X7B
from .zamba2_27b import CONFIG as ZAMBA2_27B
from .whisper_tiny import CONFIG as WHISPER_TINY
from .mamba2_13b import CONFIG as MAMBA2_13B
from .llama32_vision_90b import CONFIG as LLAMA32_VISION_90B

ARCHS: Dict[str, ModelConfig] = {
    "mistral-nemo-12b": MISTRAL_NEMO_12B,
    "qwen1.5-4b": QWEN15_4B,
    "command-r-plus-104b": COMMAND_R_PLUS_104B,
    "qwen2-0.5b": QWEN2_05B,
    "granite-moe-1b-a400m": GRANITE_MOE_1B,
    "mixtral-8x7b": MIXTRAL_8X7B,
    "zamba2-2.7b": ZAMBA2_27B,
    "whisper-tiny": WHISPER_TINY,
    "mamba2-1.3b": MAMBA2_13B,
    "llama-3.2-vision-90b": LLAMA32_VISION_90B,
}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def arch_names() -> List[str]:
    return list(ARCHS.keys())


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells; long_500k only for sub-quadratic
    archs unless include_skipped."""
    out = []
    for aname, cfg in ARCHS.items():
        for sname, shape in SHAPES.items():
            if shape.kind == "long_decode" and not cfg.sub_quadratic \
                    and not include_skipped:
                continue
            out.append((aname, sname))
    return out


__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "SMOKE_SHAPES",
           "pad_vocab", "ARCHS", "get_arch", "arch_names", "cells"]
