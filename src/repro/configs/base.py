"""Model configuration schema for the assigned architecture pool.

One frozen dataclass covers all six families (dense / moe / hybrid / audio /
ssm / vlm); family-specific fields default to "off".  ``reduced()`` returns
the structurally-identical smoke-test configuration (small widths/depths,
same family features) used by tests; the FULL configs are exercised only via
the dry-run (ShapeDtypeStruct, no allocation).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

VOCAB_PAD = 256     # embedding tables padded so vocab TP always divides


def pad_vocab(v: int) -> int:
    return -(-v // VOCAB_PAD) * VOCAB_PAD


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | audio | ssm | vlm
    num_layers: int
    d_model: int
    num_heads: int                   # 0 for attention-free (ssm)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    # attention
    qkv_bias: bool = False
    rope_theta: float = 1e4
    sliding_window: int = 0          # 0 = full attention
    max_position: int = 131072
    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    capacity_factor: float = 1.25
    # SSM (Mamba-2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_kernel: int = 4
    # hybrid (Zamba-2): one shared full-attention block every k SSM layers
    shared_attn_every: int = 0
    # encoder-decoder (Whisper): encoder frames come pre-embedded (stub)
    encoder_layers: int = 0
    encoder_seq: int = 0
    # VLM: every k-th decoder layer cross-attends to patch embeddings (stub)
    cross_attn_every: int = 0
    vision_patches: int = 0
    # misc
    act: str = "swiglu"              # swiglu | gelu
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    notes: str = ""
    # ---- performance knobs (SS Perf hillclimb levers) -------------------
    compute_dtype: str = "bf16"      # declared activation dtype: the one
    #                                  source of truth keying tuning-cache
    #                                  lookups and SOL capacity estimates.
    #                                  Must match models.layers.COMPUTE_DTYPE
    #                                  (build_model enforces this) until the
    #                                  substrate grows per-config compute
    #                                  dtypes.
    remat_policy: str = "full"       # full | dots | none
    ssd_chunk: int = 256             # Mamba-2 SSD chunk length
    ssd_impl: str = "parallel"       # parallel (all-chunks materialized) |
    #                                  scan (chunk-at-a-time, VMEM-like)
    attn_chunk_kv: int = 512         # flash-attention KV chunk (XLA path)
    cast_params_once: bool = False   # bf16-cast params before use (halves
    #                                  FSDP all-gather bytes)
    prefill_last_only: bool = False  # prefill emits last-position logits
    #                                  only (serving semantics) instead of
    #                                  the full (B,S,V) tensor
    fused_decode: bool = False       # decode block uses the fused
    #                                  residual+rmsnorm+projection step
    #                                  (maps to the DSL fusion pass's
    #                                  rmsnorm_gemm kernel on TPU); outputs
    #                                  are bitwise identical either way —
    #                                  the win is fewer kernel dispatches
    #                                  and HBM round-trips per step
    weight_dtype: str = "none"       # "none" keeps fp weights; "int8" /
    #                                  "fp8_e4m3" / "fp8_e5m2" quantizes
    #                                  attention+MLP projection (and
    #                                  untied lm-head) weights ONCE at
    #                                  engine load and routes decode
    #                                  through the dequant-fused step (the
    #                                  DSL wdtype lever / rmsnorm_gemm_q8
    #                                  kernel on TPU).  Decode is memory-
    #                                  bound on weight bytes, so int8 cuts
    #                                  per-step weight traffic ~4x at a
    #                                  rel-error cost the tuner checks
    #                                  against a budget.  REPRO_QUANT=off
    #                                  is the escape hatch.
    tp_shards: int = 1               # tensor-parallel shards for the serve
    #                                  decode path: >1 places params/cache
    #                                  with sharding.plan.ShardPlan over a
    #                                  (data=1, model=tp) mesh so GSPMD
    #                                  runs the decode projections tensor-
    #                                  parallel.  Requires tp local
    #                                  devices; the SOL-predicted per-step
    #                                  interconnect traffic is reported as
    #                                  wire_bytes_per_step, and a measured
    #                                  shard:decode_block veto ({"tp": 1})
    #                                  in the tuning cache can turn
    #                                  sharding off (never silently on).
    spec_decode: str = "off"         # speculative decoding: "off" or
    #                                  "ngram:<k>" — draft k tokens with the
    #                                  prompt-lookup self-drafter
    #                                  (serve/spec.py) and verify them in
    #                                  ONE prefill_step forward.  Decode is
    #                                  memory-bound on weight bytes, so the
    #                                  verify step costs ~1x weight traffic
    #                                  for up to k+1 emitted tokens; outputs
    #                                  are bitwise-equal to greedy decode by
    #                                  construction (accept = longest prefix
    #                                  matching greedy argmax, reject =
    #                                  exact cache rollback).  Unlike quant/
    #                                  sharding, a measured spec:decode_block
    #                                  record can turn spec ON as well as
    #                                  off (it is lossless); structural
    #                                  gates (audio/vlm families, wrapping
    #                                  sliding windows, temperature > 0
    #                                  requests) always force it off, and
    #                                  REPRO_SPEC=off is the escape hatch.
    page_size: int = 0               # block-paged decode cache: tokens per
    #                                  KV page (0 = dense per-slot cache).
    #                                  The engine allocates a global page
    #                                  pool + int32 page table instead of
    #                                  max_batch*max_len dense rows, so HBM
    #                                  scales with TOKENS IN FLIGHT, not
    #                                  worst-case context — 16-64 is the
    #                                  sweet spot (smaller = less padding
    #                                  waste, larger = smaller tables).
    #                                  Outputs are bitwise-equal to dense
    #                                  (pages gather to the same rows the
    #                                  dense kernel reads); structural gates
    #                                  (wrapping sliding windows, enc-dec
    #                                  families) force it off, and
    #                                  REPRO_PAGED=off is the escape hatch.

    # ---- derived -------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def padded_vocab(self) -> int:
        return pad_vocab(self.vocab_size)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder_layers > 0

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (linear-cost decode over context)."""
        return (self.family in ("ssm", "hybrid")
                or self.sliding_window > 0)

    @property
    def uses_attention(self) -> bool:
        return self.num_heads > 0

    # ---- parameter counting (for MODEL_FLOPS = 6*N*D) -------------------
    def _attn_params(self) -> int:
        hd = self.resolved_head_dim
        q = self.d_model * self.num_heads * hd
        kv = 2 * self.d_model * self.num_kv_heads * hd
        o = self.num_heads * hd * self.d_model
        b = (self.num_heads * hd + 2 * self.num_kv_heads * hd
             if self.qkv_bias else 0)
        return q + kv + o + b

    def _mlp_params(self, d_ff: Optional[int] = None) -> int:
        ff = d_ff or self.d_ff
        mult = 3 if self.act == "swiglu" else 2
        return mult * self.d_model * ff

    def _ssm_params(self) -> int:
        di, n, h = self.d_inner, self.ssm_state, self.ssm_heads
        in_proj = self.d_model * (2 * di + 2 * n + h)
        conv = self.conv_kernel * (di + 2 * n)
        out_proj = di * self.d_model
        extra = h + h + di            # A, dt bias, gate norm
        return in_proj + conv + out_proj + extra

    def param_count(self, active_only: bool = False) -> int:
        """Approximate parameter count (embeddings included once)."""
        n = self.padded_vocab * self.d_model     # embed
        if not self.tie_embeddings:
            n += self.padded_vocab * self.d_model  # lm head
        per_layer_norms = 2 * self.d_model

        if self.family in ("dense", "vlm", "audio"):
            layer = self._attn_params() + self._mlp_params() + per_layer_norms
            n += self.num_layers * layer
            if self.family == "vlm" and self.cross_attn_every:
                n_cross = self.num_layers // self.cross_attn_every
                n += n_cross * (self._attn_params() + self.d_model)
            if self.family == "audio":
                n += self.encoder_layers * (self._attn_params()
                                            + self._mlp_params()
                                            + per_layer_norms)
                n += self.num_layers * self._attn_params()  # cross attn
        elif self.family == "moe":
            experts = (self.num_experts_per_tok if active_only
                       else self.num_experts)
            layer = (self._attn_params() + per_layer_norms
                     + self.d_model * self.num_experts          # router
                     + experts * self._mlp_params())
            n += self.num_layers * layer
        elif self.family == "ssm":
            n += self.num_layers * (self._ssm_params() + per_layer_norms)
        elif self.family == "hybrid":
            n += self.num_layers * (self._ssm_params() + per_layer_norms)
            n += self._attn_params() + self._mlp_params() + per_layer_norms
        return n

    # ---- smoke-scale variant ---------------------------------------------
    def reduced(self) -> "ModelConfig":
        heads = min(self.num_heads, 4) if self.num_heads else 0
        kv = min(self.num_kv_heads, max(heads // 2, 1)) if heads else 0
        layers = {
            0: 0, 1: 2,
        }.get(min(self.num_layers, 1), max(2, min(4, self.num_layers)))
        if self.shared_attn_every:
            layers = 4
        if self.cross_attn_every:
            layers = 4
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=layers,
            d_model=64,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=16 if heads else 0,
            d_ff=128,
            vocab_size=512,
            max_position=512,
            sliding_window=min(self.sliding_window, 64)
            if self.sliding_window else 0,
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            num_experts_per_tok=min(self.num_experts_per_tok, 2)
            if self.num_experts_per_tok else 0,
            # smoke tests check decode==forward: avoid capacity drops at
            # tiny token counts (drop behaviour is tested separately)
            capacity_factor=4.0 if self.num_experts else 1.25,
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            shared_attn_every=2 if self.shared_attn_every else 0,
            encoder_layers=2 if self.encoder_layers else 0,
            encoder_seq=32 if self.encoder_seq else 0,
            cross_attn_every=2 if self.cross_attn_every else 0,
            vision_patches=16 if self.vision_patches else 0,
        )


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode | long_decode

    @property
    def is_decode(self) -> bool:
        return self.kind in ("decode", "long_decode")


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "long_decode"),
}

# Reduced shapes for smoke tests (same kinds, tiny dims).
SMOKE_SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 64, 2, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 64, 2, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 64, 2, "decode"),
    "long_500k": ShapeConfig("long_500k", 128, 1, "long_decode"),
}
