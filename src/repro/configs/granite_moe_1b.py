"""granite-moe-1b-a400m [moe] — 24L d_model=1024 16H (GQA kv=8) d_ff=512
vocab=49155, MoE 32 experts top-8. [hf:ibm-granite/granite-3.0-1b-a400m-base]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=8,
    d_ff=512, vocab_size=49155,
    num_experts=32, num_experts_per_tok=8,
    rope_theta=1e4, max_position=4096, tie_embeddings=True,
    notes="fine-grained MoE: 32 experts, top-8, tiny expert d_ff",
)
