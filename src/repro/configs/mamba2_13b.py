"""mamba2-1.3b [ssm] — 48L d_model=2048 attention-free, ssm_state=128,
vocab=50280, SSD (state-space duality). [arXiv:2405.21060]

Attention-free -> long_500k runs."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    num_layers=48, d_model=2048, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2,
    max_position=1048576, tie_embeddings=True,
    notes="pure Mamba-2 SSD stack",
)
