"""llama-3.2-vision-90b [vlm] — 100L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256, cross-attention image layers every 5th layer; vision frontend
STUB (precomputed patch embeddings). [hf:meta-llama/Llama-3.2-90B-Vision]

Full attention -> long_500k skipped."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b", family="vlm",
    num_layers=100, d_model=8192, num_heads=64, num_kv_heads=8,
    head_dim=128, d_ff=28672, vocab_size=128256,
    cross_attn_every=5, vision_patches=1024,
    rope_theta=5e5, max_position=131072,
    notes="decoder w/ interleaved cross-attention to patch embeddings",
)
