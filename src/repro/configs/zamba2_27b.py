"""zamba2-2.7b [hybrid] — 54L d_model=2560 Mamba-2 backbone with ONE shared
full-attention block (32H, d_ff=10240) applied every 6 layers, ssm_state=64,
vocab=32000. [arXiv:2411.15242]

Linear-cost SSM backbone -> long_500k runs."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32,
    head_dim=80, d_ff=10240, vocab_size=32000,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2,
    shared_attn_every=6, rope_theta=1e4, max_position=4096,
    tie_embeddings=True,
    notes="Mamba-2 layers + one weight-shared attention block",
)
