"""qwen2-0.5b [dense] — 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151936, QKV bias. [arXiv:2407.10671]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b", family="dense",
    num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
    d_ff=4864, vocab_size=151936,
    qkv_bias=True, rope_theta=1e6, max_position=131072,
    tie_embeddings=True,
    notes="near-MQA (kv=2) decode roofline case",
)
