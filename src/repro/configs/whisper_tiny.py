"""whisper-tiny [audio] — 4L enc + 4L dec, d_model=384 6H d_ff=1536
vocab=51865, enc-dec with conv frontend STUB (input_specs provides
precomputed frame embeddings). [arXiv:2212.04356]

Full attention -> long_500k skipped."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="audio",
    num_layers=4, d_model=384, num_heads=6, num_kv_heads=6,
    d_ff=1536, vocab_size=51865,
    encoder_layers=4, encoder_seq=1500,
    act="gelu", rope_theta=0.0, max_position=2048, tie_embeddings=True,
    notes="enc-dec backbone; audio frontend stubbed to frame embeddings",
)
