"""Training step factory: loss, grad, clip, AdamW update — pjit-ready.

The step is a pure function over (TrainState, batch); shardings come from
``repro.sharding.rules``.  Supports microbatch gradient accumulation
(lax.scan over microbatches) and bf16 cross-pod gradient compression with
error feedback (DESIGN.md distributed-optimization tricks).
"""

from __future__ import annotations

import functools
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models.model import Model
from ..optim.adamw import (AdamWConfig, AdamWState, adamw_init, adamw_update,
                           compress_grads, decompress_grads)


class TrainState(NamedTuple):
    params: Dict
    opt: AdamWState


def make_loss_fn(model: Model, xent_chunk: int = 512):
    """Cross-entropy computed in sequence chunks so the (B, S, V) logits
    tensor is never materialized — per-chunk logits stay O(B*chunk*V/TP)."""
    cfg = model.cfg

    def loss_fn(params, batch):
        x, aux = model.forward_hidden(params, batch)       # (B, S, D)
        labels = batch["labels"]
        b, s, d = x.shape
        chunk = min(xent_chunk, s)
        nc = s // chunk if s % chunk == 0 else 1
        chunk = s // nc
        xc = x.reshape(b, nc, chunk, d).swapaxes(0, 1)     # (nc, B, c, D)
        lc = labels.reshape(b, nc, chunk).swapaxes(0, 1)
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size

        def chunk_nll(carry, inp):
            xk, lk = inp
            logits = model.logits_of(params, xk)           # (B, c, Vp) f32
            logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
            logz = jax.scipy.special.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(
                logits, lk[..., None], axis=-1)[..., 0]
            return carry + jnp.sum(logz - gold), None

        total, _ = jax.lax.scan(jax.checkpoint(chunk_nll),
                                jnp.zeros((), jnp.float32), (xc, lc))
        nll = total / (b * s)
        return nll + 0.01 * aux, {"nll": nll, "aux": aux}

    return loss_fn


def init_state(model: Model, rng) -> TrainState:
    params = model.init(rng)
    return TrainState(params=params, opt=adamw_init(params))


def make_train_step(model: Model, opt_cfg: Optional[AdamWConfig] = None,
                    grad_accum: int = 1, compress_cross_pod: bool = False):
    opt_cfg = opt_cfg or AdamWConfig()
    loss_fn = make_loss_fn(model)

    if model.cfg.cast_params_once:
        # SS Perf lever: bf16-cast params ONCE at step start so FSDP
        # all-gathers move 2-byte tensors (convert-before-gather)
        inner_loss = loss_fn

        def loss_fn(params, batch):  # noqa: F811
            cast = jax.tree.map(
                lambda p: p.astype(jnp.bfloat16)
                if p.dtype == jnp.float32 and p.ndim >= 2 else p, params)
            return inner_loss(cast, batch)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch: Dict):
        if grad_accum > 1:
            def micro(carry, mb):
                acc, loss_acc = carry
                (loss, _), grads = grad_fn(state.params, mb)
                acc = jax.tree.map(jnp.add, acc, grads)
                return (acc, loss_acc + loss), None
            micro_batches = jax.tree.map(
                lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum)
                                    + x.shape[1:]), batch)
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (grads, loss_sum), _ = jax.lax.scan(
                micro, (zero, jnp.zeros(())), micro_batches)
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            loss = loss_sum / grad_accum
            metrics = {"nll": loss}
        else:
            (loss, metrics), grads = grad_fn(state.params, batch)

        if compress_cross_pod:
            # bf16 gradients for the (DCN-dominated) all-reduce; jit-level
            # error feedback is carried in optimizer metrics for simplicity
            grads, _ = compress_grads(grads)
            grads = decompress_grads(grads)

        params, opt, opt_metrics = adamw_update(
            opt_cfg, state.params, grads, state.opt)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return TrainState(params, opt), metrics

    return train_step


def make_prefill_step(model: Model):
    """Inference prefill: forward over the prompt.

    With ``cfg.prefill_last_only`` (SS Perf lever) only the last position's
    logits are computed — the (B, S, V) logits tensor (hundreds of GB at
    32k x 256k-vocab scale) never exists; serving only samples from the
    final position anyway.
    """

    def prefill_step(params, batch):
        if model.cfg.prefill_last_only:
            x, _ = model.forward_hidden(params, batch)
            return model.logits_of(params, x[:, -1:])
        logits, _ = model.forward(params, batch)
        return logits

    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, cache, tokens):
        return model.decode_step(params, cache, tokens)

    return decode_step
