"""Training driver: data -> step -> checkpoint -> supervisor heartbeats.

Restartable: ``train(...)`` resumes from the latest committed checkpoint
(params, optimizer state, AND the data-stream step, since batches are pure
functions of (seed, step)).
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint.ckpt import (AsyncCheckpointer, latest_step,
                               restore_checkpoint)
from ..data.pipeline import DataConfig, TokenSource
from ..ft.supervisor import Supervisor
from ..models.model import Model
from ..optim.adamw import AdamWConfig
from .step import TrainState, init_state, make_train_step


@dataclass
class TrainLoopConfig:
    steps: int = 100
    ckpt_every: int = 25
    ckpt_dir: Optional[str] = None
    log_every: int = 10
    seed: int = 0


def train(model: Model, data_cfg: DataConfig,
          loop_cfg: TrainLoopConfig = TrainLoopConfig(),
          opt_cfg: Optional[AdamWConfig] = None,
          supervisor: Optional[Supervisor] = None,
          log_fn: Callable[[str], None] = print) -> Dict:
    """Single-host training loop (the per-host body of the pod launcher)."""
    rng = jax.random.PRNGKey(loop_cfg.seed)
    state = init_state(model, rng)
    start_step = 0
    ckpt = AsyncCheckpointer()
    if loop_cfg.ckpt_dir and latest_step(loop_cfg.ckpt_dir) is not None:
        state, restored = restore_checkpoint(state, loop_cfg.ckpt_dir)
        start_step = restored + 1
        log_fn(f"restored checkpoint at step {restored}; resuming")

    step_fn = jax.jit(make_train_step(model, opt_cfg))
    source = TokenSource(data_cfg)
    losses = []
    for step in range(start_step, loop_cfg.steps):
        t0 = time.perf_counter()
        batch = {k: jnp.asarray(v)
                 for k, v in source.global_batch_at(step).items()}
        state, metrics = step_fn(state, batch)
        dt = time.perf_counter() - t0
        losses.append(float(metrics["loss"]))
        if supervisor is not None:
            supervisor.heartbeat(data_cfg.host_id, step, dt)
        if loop_cfg.log_every and step % loop_cfg.log_every == 0:
            log_fn(f"step {step}: loss={losses[-1]:.4f} "
                   f"lr={float(metrics['lr']):.2e} {dt*1e3:.0f}ms")
        if loop_cfg.ckpt_dir and (step + 1) % loop_cfg.ckpt_every == 0:
            ckpt.save(state, loop_cfg.ckpt_dir, step)
            if supervisor is not None:
                ckpt.wait()
                supervisor.checkpoint_committed(step)
    ckpt.wait()
    return {"final_loss": losses[-1] if losses else None,
            "losses": losses, "last_step": loop_cfg.steps - 1}
