"""Deterministic sharded data pipeline.

Synthetic-token generator with real multi-host semantics: each host produces
only its shard of the global batch (host_id/num_hosts slicing), batches are
reproducible from (seed, step) alone — which is what makes checkpoint/restart
and straggler re-balancing deterministic — and a background-prefetch iterator
hides host latency.

A real deployment would swap ``TokenSource`` for a tokenized corpus reader;
everything downstream (sharding, restart semantics) is source-agnostic.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    vocab_size: int
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0
    # "random" tokens are incompressible (loss floor = ln(vocab));
    # "structured" emits learnable arithmetic token sequences so training
    # demos can show the loss actually falling.
    kind: str = "random"

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.num_hosts == 0, (
            f"global batch {self.global_batch} must divide over "
            f"{self.num_hosts} hosts")
        return self.global_batch // self.num_hosts


class TokenSource:
    """Reproducible synthetic LM batches: batch(step) is a pure function."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        # independent stream per (seed, step, host)
        rng = np.random.Generator(np.random.Philox(
            key=cfg.seed, counter=[0, 0, step, cfg.host_id]))
        if cfg.kind == "structured":
            # learnable arithmetic sequences: t_{i+1} = t_i + stride (mod V)
            start = rng.integers(0, cfg.vocab_size, (cfg.host_batch, 1))
            stride = rng.integers(1, 8, (cfg.host_batch, 1))
            idx = np.arange(cfg.seq_len + 1)[None, :]
            tokens = ((start + stride * idx) % cfg.vocab_size).astype(
                np.int32)
        else:
            tokens = rng.integers(0, cfg.vocab_size,
                                  (cfg.host_batch, cfg.seq_len + 1),
                                  dtype=np.int32)
        return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}

    def global_batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """All hosts' shards concatenated (single-process testing)."""
        import dataclasses
        parts = []
        for h in range(self.cfg.num_hosts):
            src = TokenSource(dataclasses.replace(self.cfg, host_id=h))
            parts.append(src.batch_at(step))
        return {k: np.concatenate([p[k] for p in parts], axis=0)
                for k in parts[0]}


class PrefetchIterator:
    """Background-thread prefetch over a TokenSource, restartable at a step."""

    def __init__(self, source: TokenSource, start_step: int = 0,
                 prefetch: int = 2):
        self.source = source
        self.step = start_step
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            batch = self.source.batch_at(step)
            try:
                self._q.put((step, batch), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        step, batch = self._q.get()
        self.step = step + 1
        return step, batch

    def close(self):
        self._stop.set()
