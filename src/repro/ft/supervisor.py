"""Fault-tolerance control plane: heartbeats, failure detection, elastic
restart decisions, straggler mitigation.

Hardware-independent by design: the supervisor consumes *events* (heartbeats
with step + step-duration per worker) and emits *actions* (restart from
checkpoint, shrink/expand the mesh, re-balance data shards).  On a real
cluster the events come from the pod runtime; in tests they are simulated —
which is exactly how the policy logic should be validated anyway.
"""

from __future__ import annotations

import statistics
import time
from dataclasses import dataclass, field
from enum import Enum
from typing import Dict, List, Optional, Tuple


class WorkerState(str, Enum):
    HEALTHY = "healthy"
    SUSPECT = "suspect"
    DEAD = "dead"


@dataclass
class WorkerStatus:
    worker_id: int
    last_heartbeat: float = 0.0
    last_step: int = -1
    step_seconds: List[float] = field(default_factory=list)
    state: WorkerState = WorkerState.HEALTHY

    def mean_step_time(self) -> Optional[float]:
        if not self.step_seconds:
            return None
        return statistics.fmean(self.step_seconds[-16:])


@dataclass(frozen=True)
class Action:
    kind: str          # restart | remesh | rebalance | none
    detail: str = ""
    restore_step: Optional[int] = None
    new_num_workers: Optional[int] = None
    slow_workers: Tuple[int, ...] = ()


@dataclass
class SupervisorConfig:
    heartbeat_timeout_s: float = 60.0
    suspect_after_s: float = 20.0
    straggler_ratio: float = 1.5     # >1.5x median step time => straggler
    min_workers: int = 1


class Supervisor:
    """Tracks worker health; decides restart/remesh/rebalance actions."""

    def __init__(self, num_workers: int, cfg: SupervisorConfig = SupervisorConfig(),
                 clock=time.monotonic):
        self.cfg = cfg
        self.clock = clock
        self.workers: Dict[int, WorkerStatus] = {
            i: WorkerStatus(i, last_heartbeat=clock())
            for i in range(num_workers)
        }
        self.last_committed_step: int = -1

    # ---- event ingestion ---------------------------------------------
    def heartbeat(self, worker_id: int, step: int,
                  step_seconds: Optional[float] = None) -> None:
        w = self.workers[worker_id]
        w.last_heartbeat = self.clock()
        w.last_step = max(w.last_step, step)
        if step_seconds is not None:
            w.step_seconds.append(step_seconds)
        if w.state is not WorkerState.DEAD:
            w.state = WorkerState.HEALTHY

    def checkpoint_committed(self, step: int) -> None:
        self.last_committed_step = max(self.last_committed_step, step)

    # ---- policy ---------------------------------------------------------
    def _refresh_states(self) -> None:
        now = self.clock()
        for w in self.workers.values():
            if w.state is WorkerState.DEAD:
                continue
            idle = now - w.last_heartbeat
            if idle > self.cfg.heartbeat_timeout_s:
                w.state = WorkerState.DEAD
            elif idle > self.cfg.suspect_after_s:
                w.state = WorkerState.SUSPECT

    def healthy_workers(self) -> List[int]:
        self._refresh_states()
        return [i for i, w in self.workers.items()
                if w.state is WorkerState.HEALTHY]

    def stragglers(self) -> List[int]:
        """Workers whose recent step time exceeds straggler_ratio x median."""
        times = {i: w.mean_step_time() for i, w in self.workers.items()
                 if w.state is WorkerState.HEALTHY and w.mean_step_time()}
        if len(times) < 3:
            return []
        med = statistics.median(times.values())
        return [i for i, t in times.items()
                if t > self.cfg.straggler_ratio * med]

    def decide(self) -> Action:
        """The control loop body: failure > straggler > steady state."""
        self._refresh_states()
        dead = [i for i, w in self.workers.items()
                if w.state is WorkerState.DEAD]
        if dead:
            alive = len(self.workers) - len(dead)
            if alive < self.cfg.min_workers:
                return Action("none",
                              detail=f"{len(dead)} dead, below min_workers; "
                                     "waiting for replacements")
            # elastic shrink: restart the remaining workers from the last
            # committed checkpoint on a smaller mesh
            return Action(
                "remesh",
                detail=f"workers {dead} failed; shrink to {alive} and "
                       f"restart from step {self.last_committed_step}",
                restore_step=self.last_committed_step,
                new_num_workers=alive)
        slow = self.stragglers()
        if slow:
            # deterministic mitigation: shift data shards away from the
            # slow hosts (the pipeline re-slices by host_id -> no state to
            # migrate because batches are pure functions of (seed, step))
            return Action("rebalance",
                          detail=f"stragglers {slow}: shrink their data "
                                 "shard by half",
                          slow_workers=tuple(slow))
        return Action("none", detail="steady state")

    # ---- elastic data re-balance ---------------------------------------
    @staticmethod
    def rebalanced_shares(num_workers: int, slow: Tuple[int, ...],
                          slow_factor: float = 0.5) -> List[float]:
        """Per-worker batch shares after slowing workers are down-weighted;
        shares sum to 1 and fast workers absorb the remainder evenly."""
        shares = [1.0] * num_workers
        for i in slow:
            shares[i] = slow_factor
        total = sum(shares)
        return [s / total for s in shares]
