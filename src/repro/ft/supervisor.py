"""Fault-tolerance control plane: heartbeats, failure detection, elastic
restart decisions, straggler mitigation.

Hardware-independent by design: the supervisor consumes *events* (heartbeats
with step + step-duration per worker) and emits *actions* (restart from
checkpoint, shrink/expand the mesh, re-balance data shards).  On a real
cluster the events come from the pod runtime; in tests they are simulated —
which is exactly how the policy logic should be validated anyway.

Two supervisors share the HEALTHY -> SUSPECT -> DEAD detector:

* :class:`Supervisor` — the training control plane (wall-clock heartbeats
  from train workers; emits remesh / rebalance actions),
* :class:`ReplicaSupervisor` — the serving control plane (tick-based
  heartbeats from engine replicas behind the router; emits budgeted
  ``restart`` actions so a tripped circuit breaker or lost heartbeat
  triggers supervised restart with prefix-cache warm handoff).
"""

from __future__ import annotations

import statistics
import time
from dataclasses import dataclass, field
from enum import Enum
from typing import Dict, List, Optional, Tuple


class WorkerState(str, Enum):
    HEALTHY = "healthy"
    SUSPECT = "suspect"
    DEAD = "dead"


@dataclass
class WorkerStatus:
    worker_id: int
    last_heartbeat: float = 0.0
    last_step: int = -1
    step_seconds: List[float] = field(default_factory=list)
    state: WorkerState = WorkerState.HEALTHY

    def mean_step_time(self) -> Optional[float]:
        if not self.step_seconds:
            return None
        return statistics.fmean(self.step_seconds[-16:])


@dataclass(frozen=True)
class Action:
    kind: str          # restart | remesh | rebalance | give_up | none
    detail: str = ""
    restore_step: Optional[int] = None
    new_num_workers: Optional[int] = None
    slow_workers: Tuple[int, ...] = ()
    replica_id: Optional[int] = None   # serving: which replica to restart


@dataclass
class SupervisorConfig:
    heartbeat_timeout_s: float = 60.0
    suspect_after_s: float = 20.0
    straggler_ratio: float = 1.5     # >1.5x median step time => straggler
    min_workers: int = 1


class Supervisor:
    """Tracks worker health; decides restart/remesh/rebalance actions."""

    def __init__(self, num_workers: int, cfg: SupervisorConfig = SupervisorConfig(),
                 clock=time.monotonic):
        self.cfg = cfg
        self.clock = clock
        self.workers: Dict[int, WorkerStatus] = {
            i: WorkerStatus(i, last_heartbeat=clock())
            for i in range(num_workers)
        }
        self.last_committed_step: int = -1

    # ---- event ingestion ---------------------------------------------
    def heartbeat(self, worker_id: int, step: int,
                  step_seconds: Optional[float] = None) -> None:
        w = self.workers[worker_id]
        w.last_heartbeat = self.clock()
        w.last_step = max(w.last_step, step)
        if step_seconds is not None:
            w.step_seconds.append(step_seconds)
        if w.state is not WorkerState.DEAD:
            w.state = WorkerState.HEALTHY

    def checkpoint_committed(self, step: int) -> None:
        self.last_committed_step = max(self.last_committed_step, step)

    # ---- policy ---------------------------------------------------------
    def _refresh_states(self) -> None:
        now = self.clock()
        for w in self.workers.values():
            if w.state is WorkerState.DEAD:
                continue
            idle = now - w.last_heartbeat
            if idle > self.cfg.heartbeat_timeout_s:
                w.state = WorkerState.DEAD
            elif idle > self.cfg.suspect_after_s:
                w.state = WorkerState.SUSPECT

    def healthy_workers(self) -> List[int]:
        self._refresh_states()
        return [i for i, w in self.workers.items()
                if w.state is WorkerState.HEALTHY]

    def stragglers(self) -> List[int]:
        """Workers whose recent step time exceeds straggler_ratio x median."""
        times = {i: w.mean_step_time() for i, w in self.workers.items()
                 if w.state is WorkerState.HEALTHY and w.mean_step_time()}
        if len(times) < 3:
            return []
        med = statistics.median(times.values())
        return [i for i, t in times.items()
                if t > self.cfg.straggler_ratio * med]

    def decide(self) -> Action:
        """The control loop body: failure > straggler > steady state."""
        self._refresh_states()
        dead = [i for i, w in self.workers.items()
                if w.state is WorkerState.DEAD]
        if dead:
            alive = len(self.workers) - len(dead)
            if alive < self.cfg.min_workers:
                return Action("none",
                              detail=f"{len(dead)} dead, below min_workers; "
                                     "waiting for replacements")
            # elastic shrink: restart the remaining workers from the last
            # committed checkpoint on a smaller mesh
            return Action(
                "remesh",
                detail=f"workers {dead} failed; shrink to {alive} and "
                       f"restart from step {self.last_committed_step}",
                restore_step=self.last_committed_step,
                new_num_workers=alive)
        slow = self.stragglers()
        if slow:
            # deterministic mitigation: shift data shards away from the
            # slow hosts (the pipeline re-slices by host_id -> no state to
            # migrate because batches are pure functions of (seed, step))
            return Action("rebalance",
                          detail=f"stragglers {slow}: shrink their data "
                                 "shard by half",
                          slow_workers=tuple(slow))
        return Action("none", detail="steady state")

    # ---- elastic data re-balance ---------------------------------------
    @staticmethod
    def rebalanced_shares(num_workers: int, slow: Tuple[int, ...],
                          slow_factor: float = 0.5) -> List[float]:
        """Per-worker batch shares after slowing workers are down-weighted;
        shares sum to 1 and fast workers absorb the remainder evenly."""
        shares = [1.0] * num_workers
        for i in slow:
            shares[i] = slow_factor
        total = sum(shares)
        return [s / total for s in shares]


# ---------------------------------------------------------------------------
# Serving replicas
# ---------------------------------------------------------------------------

@dataclass
class ReplicaSupervisorConfig:
    """Tick-based policy knobs (a *tick* is one router pump iteration, so
    every threshold is deterministic in tests and benchmarks)."""

    suspect_after_ticks: int = 3     # missed heartbeats before SUSPECT
    dead_after_ticks: int = 6        # missed heartbeats before DEAD
    max_restarts: int = 3            # per replica; beyond it: give_up


@dataclass
class ReplicaStatus:
    replica_id: int
    last_heartbeat_tick: int = 0
    state: WorkerState = WorkerState.HEALTHY
    restarts: int = 0
    last_failure: str = ""
    restart_pending: bool = False    # DEAD and restart action emitted


class ReplicaSupervisor:
    """Health tracking + restart policy for serving engine replicas.

    Events in: per-tick heartbeats from live replicas and explicit failure
    reports from the router's circuit breakers (a tripped breaker is
    conclusive — no SUSPECT grace period).  Actions out (from ``poll``):
    one budgeted ``restart`` per newly dead replica, or ``give_up`` once a
    replica has burned through ``max_restarts`` (a crash-looping replica
    must not be restarted forever into the same fault).  The router
    executes restarts and confirms them with ``restarted`` — the restarted
    engine re-adopts the shared prefix-cache snapshots (warm handoff)
    before rejoining the routing set.
    """

    def __init__(self, replica_ids,
                 cfg: ReplicaSupervisorConfig = ReplicaSupervisorConfig()):
        self.cfg = cfg
        self.replicas: Dict[int, ReplicaStatus] = {
            int(i): ReplicaStatus(int(i)) for i in replica_ids}

    # ---- event ingestion ---------------------------------------------
    def heartbeat(self, replica_id: int, tick: int) -> None:
        r = self.replicas[replica_id]
        r.last_heartbeat_tick = max(r.last_heartbeat_tick, tick)
        if r.state is not WorkerState.DEAD:
            r.state = WorkerState.HEALTHY

    def report_failure(self, replica_id: int, tick: int,
                       reason: str = "") -> None:
        """A circuit breaker tripped: the replica is conclusively dead."""
        r = self.replicas[replica_id]
        r.state = WorkerState.DEAD
        r.last_failure = reason or "breaker_tripped"

    def restarted(self, replica_id: int, tick: int) -> None:
        """Router confirmation that the replica was rebuilt and readmitted."""
        r = self.replicas[replica_id]
        r.state = WorkerState.HEALTHY
        r.last_heartbeat_tick = tick
        r.restarts += 1
        r.restart_pending = False

    # ---- policy ------------------------------------------------------
    def state_of(self, replica_id: int) -> WorkerState:
        return self.replicas[replica_id].state

    def healthy_replicas(self) -> List[int]:
        return [i for i, r in self.replicas.items()
                if r.state is WorkerState.HEALTHY]

    def poll(self, tick: int) -> List[Action]:
        """The control loop body: refresh heartbeat-derived states, then
        emit exactly one restart (or give_up) action per newly dead
        replica.  Actions are emitted once — the router must answer with
        ``restarted`` before another restart can be issued."""
        actions: List[Action] = []
        for r in self.replicas.values():
            if r.state is not WorkerState.DEAD:
                idle = tick - r.last_heartbeat_tick
                if idle >= self.cfg.dead_after_ticks:
                    r.state = WorkerState.DEAD
                    r.last_failure = r.last_failure or "heartbeat_lost"
                elif idle >= self.cfg.suspect_after_ticks:
                    r.state = WorkerState.SUSPECT
            if r.state is WorkerState.DEAD and not r.restart_pending:
                r.restart_pending = True
                if r.restarts >= self.cfg.max_restarts:
                    actions.append(Action(
                        "give_up", replica_id=r.replica_id,
                        detail=f"replica {r.replica_id} exceeded "
                               f"{self.cfg.max_restarts} restarts "
                               f"({r.last_failure})"))
                else:
                    actions.append(Action(
                        "restart", replica_id=r.replica_id,
                        detail=f"replica {r.replica_id} dead "
                               f"({r.last_failure}); supervised restart "
                               f"{r.restarts + 1}/{self.cfg.max_restarts}"))
        return actions
