"""repro: muPallas + SOL-guidance TPU kernel-optimization framework."""
__version__ = "0.1.0"
