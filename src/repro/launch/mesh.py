"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Single pod: (data=16, model=16) = 256 chips.
Multi-pod: (pod=2, data=16, model=16) = 512 chips; the 'pod' axis is the
outer data-parallel axis whose collectives cross the DCN.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh():
    """1-device mesh with the production axis names (for CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))
