"""Production mesh construction.

FUNCTIONS (not module-level constants) so importing this module never
touches jax device state.  Single pod: (data=16, model=16) = 256 chips.
Multi-pod: (pod=2, data=16, model=16) = 512 chips; the 'pod' axis is the
outer data-parallel axis whose collectives cross the DCN.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh():
    """A (data, model) mesh over ALL local devices, with the production
    axis names — for CPU tests.

    Honors ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (set
    BEFORE importing jax): N forced host devices become a real multi-device
    mesh — (2, N/2) so both axes exercise sharding when N is an even
    count >= 4, else (1, N) — instead of collapsing to the 1x1 mesh that
    silently skipped every multi-device sharding path in CI.
    """
    n = len(jax.devices())
    if n >= 4 and n % 2 == 0:
        shape = (2, n // 2)
    else:
        shape = (1, n) if n > 1 else (1, 1)
    return jax.make_mesh(shape, ("data", "model"))


def make_tp_mesh(tp: int):
    """A (data=1, model=tp) decode mesh over the first ``tp`` local
    devices — what the serve engine builds for ``ModelConfig.tp_shards``
    (sharding.plan.ShardPlan consumes it; GSPMD inserts the collectives
    the SOL model prices as ``wire_bytes_per_step``)."""
    from repro.kernels.collective import require_devices

    require_devices(tp)
    from jax.sharding import Mesh
    import numpy as np

    devs = np.asarray(jax.devices()[:tp]).reshape(1, tp)
    return Mesh(devs, ("data", "model"))
