import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This proves the distribution config is coherent without hardware:
``.lower().compile()`` must succeed on the 16x16 single-pod mesh AND the
2x16x16 multi-pod mesh for every cell; ``memory_analysis()`` proves it fits,
``cost_analysis()`` + HLO collective parsing feed SS Roofline.

The two lines above MUST stay the first statements in this module — jax
locks the device count on first init.  Run as:

    PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
        [--mesh single|multi|both] [--out runs/dryrun] [--force]
"""

import argparse        # noqa: E402
import functools       # noqa: E402
import json            # noqa: E402
import time            # noqa: E402
import traceback       # noqa: E402

import jax             # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCHS, SHAPES, cells, get_arch            # noqa: E402
from repro.core.sol.hardware import TPU_V5E                         # noqa: E402
from repro.core.sol.hlo_analysis import summarize_compiled          # noqa: E402
from repro.core.sol.roofline import roofline                        # noqa: E402
from repro.launch.mesh import make_production_mesh                  # noqa: E402
from repro.launch.specs import input_specs                          # noqa: E402
from repro.models.model import build_model                          # noqa: E402
from repro.optim.adamw import AdamWState, adamw_init                # noqa: E402
from repro.sharding.plan import ShardPlan                           # noqa: E402
from repro.train.step import (TrainState, init_state,               # noqa: E402
                              make_decode_step, make_prefill_step,
                              make_train_step)


def _apply_overrides(cfg, overrides):
    """--set key=value config overrides (SS Perf hillclimb variants)."""
    import dataclasses
    if not overrides:
        return cfg
    kw = {}
    for ov in overrides:
        key, _, val = ov.partition("=")
        cur = getattr(cfg, key)   # raises on unknown key
        if isinstance(cur, bool):
            kw[key] = val.lower() in ("1", "true", "yes")
        elif isinstance(cur, int):
            kw[key] = int(val)
        elif isinstance(cur, float):
            kw[key] = float(val)
        else:
            kw[key] = val
    return dataclasses.replace(cfg, **kw)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               overrides=()):
    """Returns (lowered, num_devices, model_flops)."""
    cfg = _apply_overrides(get_arch(arch), overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    plan = ShardPlan(mesh)
    n_dev = plan.num_devices
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)

    if shape.kind == "train":
        state_abs = jax.eval_shape(lambda: init_state(model, rng))
        state_sh = TrainState(
            params=plan.params(state_abs.params),
            opt=AdamWState(
                step=plan.replicated(),
                mu=plan.params(state_abs.opt.mu),
                nu=plan.params(state_abs.opt.nu)))
        batch_abs = input_specs(cfg, shape)
        batch_sh = plan.batch(batch_abs)
        step = make_train_step(model)
        with mesh:
            lowered = jax.jit(
                step, in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, None),
            ).lower(state_abs, batch_abs)
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * cfg.param_count(active_only=True) * tokens
    elif shape.kind == "prefill":
        params_abs = jax.eval_shape(lambda: model.init(rng))
        params_sh = plan.params(params_abs)
        batch_abs = input_specs(cfg, shape)
        batch_sh = plan.batch(batch_abs)
        step = make_prefill_step(model)
        with mesh:
            lowered = jax.jit(
                step, in_shardings=(params_sh, batch_sh),
                out_shardings=None,
            ).lower(params_abs, batch_abs)
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2.0 * cfg.param_count(active_only=True) * tokens
    else:  # decode / long_decode
        params_abs = jax.eval_shape(lambda: model.init(rng))
        params_sh = plan.params(params_abs)
        cache_abs = jax.eval_shape(functools.partial(
            model.init_cache, shape.global_batch, shape.seq_len))
        cache_sh = plan.cache(cache_abs)
        batch_abs = input_specs(cfg, shape)
        tok_sh = plan.batch(batch_abs)["tokens"]
        step = make_decode_step(model)
        with mesh:
            lowered = jax.jit(
                step, in_shardings=(params_sh, cache_sh, tok_sh),
                out_shardings=(None, cache_sh),
            ).lower(params_abs, cache_abs, batch_abs["tokens"])
        tokens = shape.global_batch
        model_flops = 2.0 * cfg.param_count(active_only=True) * tokens
    return lowered, n_dev, model_flops


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             force: bool = False, overrides=(), suffix: str = "") -> dict:
    tag = f"{arch}__{shape_name}__{mesh_kind}" + (f"__{suffix}" if suffix
                                                  else "")
    path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    t0 = time.time()
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
              "overrides": list(overrides)}
    try:
        lowered, n_dev, model_flops = lower_cell(
            arch, shape_name, multi_pod=(mesh_kind == "multi"),
            overrides=overrides)
        compiled = lowered.compile()
        summ = summarize_compiled(compiled, n_dev)
        rl = roofline(
            summ.total_flops, summ.total_hbm_bytes,
            collective_bytes=summ.per_device_collective_bytes * n_dev,
            num_chips=n_dev, dtype="bf16", chip=TPU_V5E)
        record.update({
            "ok": True,
            "num_devices": n_dev,
            "compile_seconds": time.time() - t0,
            "model_flops": model_flops,
            "useful_flops_ratio": (model_flops / summ.total_flops
                                   if summ.total_flops else None),
            "summary": summ.as_dict(),
            "roofline": rl.as_dict(),
        })
        try:
            ma = compiled.memory_analysis()
            print(f"{tag}: memory_analysis: {ma}")
        except Exception:
            pass
        ca = compiled.cost_analysis()
        print(f"{tag}: flops/device={summ.per_device_flops:.3e} "
              f"bytes/device={summ.per_device_hbm_bytes:.3e} "
              f"collective/device={summ.per_device_collective_bytes:.3e} "
              f"t_sol={rl.t_sol:.4f}s bottleneck={rl.bottleneck} "
              f"({time.time() - t0:.0f}s)")
        del ca
    except Exception as e:
        record.update({"ok": False, "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-4000:],
                       "compile_seconds": time.time() - t0})
        print(f"{tag}: FAILED {type(e).__name__}: {e}")
    os.makedirs(out_dir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(record, f, indent=1, default=str)
    return record


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="one arch (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="runs/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--set", dest="overrides", action="append", default=[],
                    metavar="KEY=VALUE",
                    help="config override, e.g. --set remat_policy=dots")
    ap.add_argument("--tag", default="",
                    help="artifact filename suffix for override variants")
    args = ap.parse_args()

    todo = cells()
    if args.arch:
        todo = [(a, s) for a, s in todo if a == args.arch]
    if args.shape:
        todo = [(a, s) for a, s in todo if s == args.shape]
    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[args.mesh]

    n_ok = n_fail = 0
    for mesh_kind in meshes:
        for arch, shape_name in todo:
            rec = run_cell(arch, shape_name, mesh_kind, args.out, args.force,
                           overrides=tuple(args.overrides), suffix=args.tag)
            if rec.get("ok"):
                n_ok += 1
            else:
                n_fail += 1
    print(f"dry-run complete: {n_ok} ok, {n_fail} failed")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
