"""Training launcher: ``python -m repro.launch.train --arch qwen2-0.5b``.

CPU-scale driver over the same model/step/data/checkpoint stack the
multi-pod dry-run lowers.  On a real pod this process runs once per host
(jax.distributed.initialize + the production mesh); flags for the
latency-hiding scheduler and async collectives are set here so
compute/communication overlap is on by default.
"""

import os

# XLA flags a real TPU launch would set (harmless on CPU):
os.environ.setdefault(
    "LIBTPU_INIT_ARGS",
    "--xla_tpu_enable_async_collective_fusion=true "
    "--xla_tpu_enable_latency_hiding_scheduler=true "
    "--xla_tpu_overlap_compute_collective_tc=true")

import argparse        # noqa: E402

from repro.configs import get_arch                     # noqa: E402
from repro.data.pipeline import DataConfig             # noqa: E402
from repro.models.model import build_model             # noqa: E402
from repro.optim.adamw import AdamWConfig              # noqa: E402
from repro.train.loop import TrainLoopConfig, train    # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    model = build_model(cfg)
    data_cfg = DataConfig(global_batch=args.batch, seq_len=args.seq,
                          vocab_size=cfg.vocab_size)
    out = train(model, data_cfg,
                TrainLoopConfig(steps=args.steps, ckpt_dir=args.ckpt_dir),
                AdamWConfig(total_steps=args.steps, warmup_steps=5))
    print(f"done: final loss {out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
