"""Serving launcher: ``python -m repro.launch.serve --arch qwen2-0.5b --smoke``."""

import argparse

import jax
import numpy as np

from repro.configs import get_arch
from repro.models.model import build_model
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, max_batch=4, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=list(rng.integers(0, cfg.vocab_size, 5)),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    done = engine.run(reqs)
    for r in done:
        print(f"req {r.rid}: prompt={r.prompt} -> {r.out_tokens}")
    print("metrics:", engine.metrics)


if __name__ == "__main__":
    main()
