"""Serving launcher.

Single-engine batch mode (drives a workload to completion and exits):

    python -m repro.launch.serve --arch qwen2-0.5b --smoke \
        --scheduler sol --prefix-cache --stream

Gateway mode (replicated engines behind the HTTP/WS front door; serves
until interrupted):

    python -m repro.launch.serve --arch qwen2-0.5b --smoke --gateway \
        --replicas 2 --port 8080 --rate-limit 50

    curl -s localhost:8080/healthz
    curl -s localhost:8080/v1/generate -d '{"prompt": [3,5,7], \
"max_new_tokens": 8, "slo": "interactive"}'
    curl -s localhost:8080/metrics
"""

import argparse

import jax
import numpy as np

from repro.configs import get_arch
from repro.models.model import build_model
from repro.serve import PrefixCache, Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--gateway", action="store_true",
                    help="serve the HTTP/WS front door over replicated "
                         "engines instead of running a one-shot workload")
    ap.add_argument("--replicas", type=int, default=2,
                    help="engine replicas behind the gateway router")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8080)
    ap.add_argument("--rate-limit", type=float, default=None,
                    help="per-SLO-class token-bucket rate (requests/s, "
                         "burst 2x); unset = unlimited")
    ap.add_argument("--max-queue", type=int, default=8,
                    help="bounded per-replica admission queue; a full "
                         "fleet answers 429 with a SOL-priced Retry-After")
    ap.add_argument("--deadline-steps", type=int, default=None,
                    help="slot-occupancy deadline (engine steps) after "
                         "which a stuck request is reclaimed (timed_out)")
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--prefill", choices=("chunked", "token"),
                    default="chunked")
    ap.add_argument("--chunk", type=int, default=16,
                    help="prefill chunk size (tokens per slot per step)")
    ap.add_argument("--scheduler", choices=("fifo", "sol"), default="fifo")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="reuse prefilled state across shared prefixes")
    ap.add_argument("--stream", action="store_true",
                    help="print tokens as they are sampled")
    ap.add_argument("--slo", choices=("interactive", "batch"),
                    default="batch", help="SLO class for the requests")
    ap.add_argument("--weight-dtype",
                    choices=("none", "int8", "fp8_e4m3", "fp8_e5m2"),
                    default=None,
                    help="quantize projection weights at load and route "
                         "decode through the dequant-fused step; unset "
                         "defers to the config + tuned verdict "
                         "(REPRO_QUANT=off overrides)")
    ap.add_argument("--spec-decode", default=None, metavar="K|off",
                    help="speculative decoding: an int drafts that many "
                         "tokens per step with the n-gram self-drafter "
                         "(\"ngram:4\" spells the drafter out), \"off\" "
                         "disables it; unset defers to the config + tuned "
                         "acceptance verdict (REPRO_SPEC=off overrides)")
    ap.add_argument("--page-size", type=int, default=None,
                    help="block-paged decode cache: tokens per KV page "
                         "(HBM scales with tokens in flight, not "
                         "max_batch*max_context; outputs stay bitwise-"
                         "equal to dense); unset defers to the config, 0 "
                         "forces dense (REPRO_PAGED=off overrides)")
    ap.add_argument("--max-context", type=int, default=None,
                    help="per-request context ceiling (prompt + new "
                         "tokens); with --page-size this bounds pages a "
                         "request can pin, not a dense allocation")
    ap.add_argument("--tp-shards", type=int, default=None,
                    help="tensor-parallel shards for the decode path "
                         "(needs that many devices; on CPU set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N); "
                         "unset defers to the config + tuned shard "
                         "verdict")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record a SOL-attributed trace: .jsonl streams "
                         "one span per line, anything else gets a "
                         "Chrome/Perfetto trace written on exit")
    args = ap.parse_args()

    tracer = None
    if args.trace:
        from repro.core.obs import configure as configure_tracer
        # the launcher exports explicitly on exit (batch mode) or relies
        # on the atexit hook (gateway mode, killed by signal)
        tracer = configure_tracer(args.trace)

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    if args.gateway:
        from repro.serve import SLO_CLASSES, build_replicated_router
        from repro.serve.gateway import run_gateway

        limits = None
        if args.rate_limit:
            limits = {slo: (args.rate_limit, 2 * args.rate_limit)
                      for slo in SLO_CLASSES}
        router = build_replicated_router(
            model, params, replicas=args.replicas, max_batch=4,
            max_len=args.max_context or (64 if args.smoke else 256),
            chunk_size=args.chunk,
            scheduler=args.scheduler, prefix_cache=args.prefix_cache,
            rate_limits=limits, max_queue_per_replica=args.max_queue,
            request_timeout_steps=args.deadline_steps,
            weight_dtype=args.weight_dtype, tp_shards=args.tp_shards,
            spec_decode=args.spec_decode, page_size=args.page_size)
        print(f"gateway: {args.replicas} replicas on "
              f"http://{args.host}:{args.port}  "
              f"(POST /v1/generate, WS /v1/stream, /healthz, /metrics, "
              f"/metrics.json)")
        if args.trace:
            print(f"tracing to {args.trace}")
        run_gateway(router, host=args.host, port=args.port)
        return

    engine = ServeEngine(
        model, params, max_batch=4, max_len=args.max_context or 64,
        prefill_mode=args.prefill, chunk_size=args.chunk,
        scheduler=args.scheduler,
        weight_dtype=args.weight_dtype,
        tp_shards=args.tp_shards,
        spec_decode=args.spec_decode,
        page_size=args.page_size,
        prefix_cache=PrefixCache(block=args.chunk) if args.prefix_cache
        else None)
    if engine.model.cfg.weight_dtype != "none":
        print(f"weight_dtype={engine.model.cfg.weight_dtype} "
              f"({engine.weight_bytes_per_step / 1e3:.1f} KB weight "
              f"traffic per decode step)")
    if engine.model.cfg.tp_shards > 1:
        print(f"tp_shards={engine.model.cfg.tp_shards} "
              f"({engine.wire_bytes_per_step / 1e3:.1f} KB SOL-predicted "
              f"interconnect traffic per decode step)")
    if engine.paged:
        st = engine.pool.stats()
        print(f"page_size={engine.page_size} "
              f"({st['pages_total']} KV pages + "
              f"{st['state_pages_total']} state pages, "
              f"{st['pool_total_bytes'] / 1e3:.1f} KB pool; HBM priced "
              f"per token in flight, admission rejects with a bytes-"
              f"priced Retry-After when the pool binds)")
    if engine.spec is not None:
        print(f"spec_decode={engine.model.cfg.spec_decode} "
              f"(E[tokens/step]={engine.expected_tokens_per_step:.2f} at "
              f"the tuned acceptance hint, {engine.spec_mode} rollback)")
    rng = np.random.default_rng(0)
    shared = list(map(int, rng.integers(0, cfg.vocab_size, args.chunk)))
    reqs = []
    for i in range(args.requests):
        # half the requests share a "system prompt" prefix so --prefix-cache
        # has something to hit
        tail = list(map(int, rng.integers(0, cfg.vocab_size, 5)))
        prompt = (shared + tail) if i % 2 == 0 else \
            list(map(int, rng.integers(0, cfg.vocab_size, 5)))
        reqs.append(Request(rid=i, prompt=prompt,
                            max_new_tokens=args.max_new, slo=args.slo))

    if args.stream:
        for ev in engine.stream(reqs):
            flag = " <end>" if ev.final else ""
            print(f"  [step {ev.step:3d}] req {ev.rid} "
                  f"token[{ev.index}] = {ev.token}{flag}")
    else:
        engine.run(reqs)
    for r in reqs:
        state = "done" if r.done else ("truncated" if r.truncated else "?")
        print(f"req {r.rid} ({state}): {len(r.prompt)}-token prompt "
              f"-> {r.out_tokens}")
    print("metrics:", engine.metrics)
    summ = engine.telemetry.summary()
    print(f"telemetry: ttft p50={summ['ttft_steps_p50']:.1f} "
          f"p95={summ['ttft_steps_p95']:.1f} steps, "
          f"util={summ['slot_utilization']:.2f}, "
          f"prefix hit rate={summ['prefix_hit_rate']:.2f}")
    if engine.prefix_cache is not None:
        print("prefix cache:", engine.prefix_cache.stats())
    if tracer is not None:
        from repro.core.obs import get_drift
        if not args.trace.endswith(".jsonl"):
            print(f"trace: {tracer.export_chrome(args.trace)} "
                  f"({len(tracer.spans())} spans, "
                  f"categories {tracer.categories()})")
        print("drift report:")
        print(get_drift().table())


if __name__ == "__main__":
    main()
