"""ShapeDtypeStruct stand-ins for every model input (no device allocation)."""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    """Batch specs for the step the shape's kind lowers.

    train   -> {tokens, labels [, frames | image_embeds]}
    prefill -> {tokens [, frames | image_embeds]}
    decode  -> {tokens: (B, 1)}  (the cache is built separately)
    """
    b = shape.global_batch
    s = shape.seq_len
    tok = jnp.int32
    if shape.kind == "train":
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), tok),
                 "labels": jax.ShapeDtypeStruct((b, s), tok)}
    elif shape.kind == "prefill":
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), tok)}
    else:  # decode / long_decode: one new token against a seq_len cache
        batch = {"tokens": jax.ShapeDtypeStruct((b, 1), tok)}
    if shape.kind in ("train", "prefill"):
        if cfg.family == "audio":
            batch["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm":
            batch["image_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.vision_patches, cfg.d_model), jnp.bfloat16)
    return batch
