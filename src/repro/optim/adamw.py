"""AdamW with decoupled weight decay, global-norm clipping, LR schedules,
and optional gradient compression for the cross-pod all-reduce.

Self-contained (no optax) so the optimizer-state pytree shape/sharding is
fully under our control for the dry-run and the elastic-resharding path.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array              # scalar int32
    mu: Dict                     # first moment  (like params)
    nu: Dict                     # second moment (like params)


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


def cosine_warmup_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * cos


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: (x * scale).astype(x.dtype), tree), norm


def adamw_init(params) -> AdamWState:
    zeros = lambda: jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros(), nu=zeros())


def adamw_update(cfg: AdamWConfig, params, grads,
                 state: AdamWState) -> Tuple[Dict, AdamWState, Dict]:
    grads, grad_norm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = cosine_warmup_lr(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        new_p = p - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                          + cfg.weight_decay * p)
        return new_p.astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(tdef, [o[2] for o in out])
    metrics = {"grad_norm": grad_norm, "lr": lr}
    return new_params, AdamWState(step, new_mu, new_nu), metrics


# ---------------------------------------------------------------------------
# Gradient compression (cross-pod traffic reduction, error feedback)
# ---------------------------------------------------------------------------

def compress_grads(grads, error_feedback=None, dtype=jnp.bfloat16):
    """Quantize gradients before the (DCN) all-reduce with error feedback.

    Returns (compressed, new_error_feedback).  bf16 halves the cross-pod
    all-reduce bytes; the quantization residual is carried to the next step
    (error feedback keeps the update unbiased in expectation).
    """
    if error_feedback is None:
        error_feedback = jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def comp(g, e):
        corrected = g.astype(jnp.float32) + e
        q = corrected.astype(dtype)
        new_e = corrected - q.astype(jnp.float32)
        return q, new_e

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error_feedback)
    pairs = [comp(g, e) for g, e in zip(flat_g, flat_e)]
    comp_g = jax.tree.unflatten(tdef, [p[0] for p in pairs])
    new_ef = jax.tree.unflatten(tdef, [p[1] for p in pairs])
    return comp_g, new_ef


def decompress_grads(grads):
    return jax.tree.map(lambda g: g.astype(jnp.float32), grads)
