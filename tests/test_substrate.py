"""Checkpointing (incl. corruption + elastic restore), training loop
restart, supervisor policy, serving engine, sharding rules."""

import json
import os

import numpy as np
import pytest

jax = pytest.importorskip("jax")
pytest.importorskip("hypothesis")
import jax.numpy as jnp  # noqa: E402
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.checkpoint.ckpt import (AsyncCheckpointer, latest_step,  # noqa: E402
                                   restore_checkpoint, save_checkpoint)
from repro.configs import get_arch  # noqa: E402
from repro.data.pipeline import DataConfig  # noqa: E402
from repro.ft.supervisor import (Action, Supervisor,  # noqa: E402
                                 SupervisorConfig, WorkerState)
from repro.launch.mesh import make_smoke_mesh  # noqa: E402
from repro.models.model import build_model  # noqa: E402
from repro.serve.engine import Request, ServeEngine  # noqa: E402
from repro.sharding.rules import (batch_spec, cache_spec,  # noqa: E402
                                  param_spec, params_shardings)
from repro.train.loop import TrainLoopConfig, train  # noqa: E402


class TestCheckpoint:
    def _tree(self):
        return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                "b": {"c": jnp.ones((5,), jnp.int32),
                      "d": jnp.zeros((), jnp.float32)}}

    def test_roundtrip(self, tmp_path):
        tree = self._tree()
        save_checkpoint(tree, str(tmp_path), step=7)
        assert latest_step(str(tmp_path)) == 7
        restored, step = restore_checkpoint(tree, str(tmp_path))
        assert step == 7
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.asarray(tree["a"]))

    def test_corruption_detected(self, tmp_path):
        tree = self._tree()
        path = save_checkpoint(tree, str(tmp_path), step=1)
        # corrupt a shard
        target = os.path.join(path, "a.npy")
        arr = np.load(target)
        arr.flat[0] += 1
        np.save(target, arr)
        with pytest.raises(IOError, match="checksum"):
            restore_checkpoint(tree, str(tmp_path))

    def test_torn_checkpoint_ignored(self, tmp_path):
        tree = self._tree()
        save_checkpoint(tree, str(tmp_path), step=3)
        torn = os.path.join(str(tmp_path), "step_000000009")
        os.makedirs(torn)                      # no COMMIT file
        assert latest_step(str(tmp_path)) == 3

    def test_elastic_restore_onto_mesh(self, tmp_path):
        """Checkpoint saved without a mesh restores with shardings (the
        resharding path used when the pod size changes)."""
        tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
        save_checkpoint(tree, str(tmp_path), step=0, mesh_shape=(16, 16))
        mesh = make_smoke_mesh()
        sh = params_shardings(tree, mesh)
        restored, _ = restore_checkpoint(tree, str(tmp_path), shardings=sh)
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(tree["w"]))

    def test_async_checkpointer(self, tmp_path):
        ck = AsyncCheckpointer()
        ck.save(self._tree(), str(tmp_path), step=11)
        ck.wait()
        assert latest_step(str(tmp_path)) == 11


class TestTrainLoopRestart:
    def test_resume_from_checkpoint(self, tmp_path):
        cfg = get_arch("qwen2-0.5b").reduced()
        model = build_model(cfg)
        data = DataConfig(global_batch=2, seq_len=16,
                          vocab_size=cfg.vocab_size)
        loop = TrainLoopConfig(steps=4, ckpt_every=2,
                               ckpt_dir=str(tmp_path), log_every=0)
        out1 = train(model, data, loop, log_fn=lambda s: None)
        # crash-and-restart: a fresh invocation resumes past step 1
        loop2 = TrainLoopConfig(steps=6, ckpt_every=2,
                                ckpt_dir=str(tmp_path), log_every=0)
        out2 = train(model, data, loop2, log_fn=lambda s: None)
        assert out2["last_step"] == 5
        assert np.isfinite(out2["final_loss"])


class TestSupervisor:
    def test_failure_triggers_remesh(self):
        clock = [0.0]
        sup = Supervisor(4, SupervisorConfig(heartbeat_timeout_s=10),
                         clock=lambda: clock[0])
        for w in range(4):
            sup.heartbeat(w, step=5, step_seconds=1.0)
        sup.checkpoint_committed(4)
        clock[0] = 30.0
        for w in (0, 1, 2):
            sup.heartbeat(w, step=6, step_seconds=1.0)
        act = sup.decide()
        assert act.kind == "remesh"
        assert act.new_num_workers == 3
        assert act.restore_step == 4

    def test_straggler_rebalance(self):
        clock = [0.0]
        sup = Supervisor(4, clock=lambda: clock[0])
        for step in range(6):
            for w in range(4):
                sup.heartbeat(w, step, step_seconds=3.0 if w == 2 else 1.0)
        act = sup.decide()
        assert act.kind == "rebalance"
        assert act.slow_workers == (2,)
        shares = Supervisor.rebalanced_shares(4, (2,))
        assert abs(sum(shares) - 1.0) < 1e-9
        assert shares[2] < shares[0]

    def test_steady_state(self):
        sup = Supervisor(2)
        for w in range(2):
            sup.heartbeat(w, 0, 1.0)
        assert sup.decide().kind == "none"


class TestServeEngine:
    def test_continuous_batching_end_to_end(self):
        cfg = get_arch("qwen2-0.5b").reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        engine = ServeEngine(model, params, max_batch=2, max_len=32)
        rng = np.random.default_rng(0)
        reqs = [Request(rid=i,
                        prompt=list(map(int, rng.integers(
                            0, cfg.vocab_size, 4))),
                        max_new_tokens=5)
                for i in range(4)]     # 4 requests > 2 slots: slot reuse
        done = engine.run(reqs)
        assert all(len(r.out_tokens) == 5 for r in done)
        assert engine.metrics["requests_done"] == 4

    def test_slot_isolation(self):
        """A request's output must not depend on co-batched requests."""
        cfg = get_arch("qwen2-0.5b").reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        prompt = [3, 5, 7, 11]

        solo = ServeEngine(model, params, max_batch=2, max_len=32)
        [r_solo] = solo.run([Request(rid=0, prompt=prompt,
                                     max_new_tokens=4)])
        pair = ServeEngine(model, params, max_batch=2, max_len=32)
        rs = pair.run([Request(rid=0, prompt=prompt, max_new_tokens=4),
                       Request(rid=1, prompt=[2, 4, 6, 8],
                               max_new_tokens=4)])
        assert rs[0].out_tokens == r_solo.out_tokens


class TestShardingRules:
    def test_param_spec_divisibility(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        spec = param_spec("layers/attn/wq", (24, 896, 896), mesh)
        # 1-sized axes: nothing sharded
        assert all(s is None for s in spec)

    @settings(max_examples=40, deadline=None)
    @given(d0=st.sampled_from([7, 64, 896, 12288]),
           d1=st.sampled_from([13, 128, 14336, 49155]))
    def test_specs_always_divide(self, d0, d1):
        """property: any dim the rules shard must divide the axis size."""
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        axis_sizes = {"data": 1, "model": 1}
        spec = param_spec("layers/mlp/w", (d0, d1), mesh)
        shape = (d0, d1)
        for dim, ax in enumerate(spec):
            if ax is not None:
                assert shape[dim] % axis_sizes[ax] == 0

    def test_batch_spec(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        assert batch_spec((256, 4096), mesh)[0] is not None or \
            mesh.shape["data"] == 1

    def test_cache_spec_pos_replicated(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        spec = cache_spec("layers/pos", (24, 128), mesh)
        assert all(s is None for s in spec)
