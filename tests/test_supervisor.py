"""Supervisor policy logic: heartbeat state walks, straggler detection,
and action emission — for both the training control plane (`Supervisor`)
and the serving control plane (`ReplicaSupervisor`).

All hardware-independent: events are simulated (fake clocks / explicit
ticks), which is how the policy should be validated anyway.
"""

from repro.ft.supervisor import (ReplicaSupervisor, ReplicaSupervisorConfig,
                                 Supervisor, SupervisorConfig, WorkerState)


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


class TestTrainSupervisorStates:
    def make(self, n=3):
        clock = FakeClock()
        sup = Supervisor(n, SupervisorConfig(heartbeat_timeout_s=60.0,
                                             suspect_after_s=20.0),
                         clock=clock)
        return sup, clock

    def test_healthy_to_suspect_to_dead(self):
        sup, clock = self.make()
        for i in range(3):
            sup.heartbeat(i, step=1)
        assert sup.healthy_workers() == [0, 1, 2]
        # worker 2 goes quiet; the others keep beating
        clock.advance(25.0)
        sup.heartbeat(0, step=2)
        sup.heartbeat(1, step=2)
        sup.healthy_workers()
        assert sup.workers[2].state is WorkerState.SUSPECT
        clock.advance(40.0)              # 65s idle total > timeout
        sup.heartbeat(0, step=3)
        sup.heartbeat(1, step=3)
        sup.healthy_workers()
        assert sup.workers[2].state is WorkerState.DEAD

    def test_suspect_recovers_on_heartbeat(self):
        sup, clock = self.make()
        clock.advance(25.0)
        sup.healthy_workers()
        assert sup.workers[0].state is WorkerState.SUSPECT
        sup.heartbeat(0, step=1)
        assert sup.workers[0].state is WorkerState.HEALTHY

    def test_dead_stays_dead_despite_heartbeat(self):
        """A declared-dead worker must not flap back on a late heartbeat —
        only the restart path readmits it."""
        sup, clock = self.make()
        clock.advance(100.0)
        sup.healthy_workers()
        assert sup.workers[1].state is WorkerState.DEAD
        sup.heartbeat(1, step=5)
        assert sup.workers[1].state is WorkerState.DEAD

    def test_remesh_restores_last_committed_step(self):
        sup, clock = self.make()
        sup.checkpoint_committed(40)
        sup.checkpoint_committed(30)     # out-of-order commit is ignored
        clock.advance(100.0)
        sup.heartbeat(0, step=50)
        sup.heartbeat(1, step=50)
        act = sup.decide()
        assert act.kind == "remesh"
        assert act.restore_step == 40
        assert act.new_num_workers == 2

    def test_below_min_workers_waits(self):
        clock = FakeClock()
        sup = Supervisor(2, SupervisorConfig(min_workers=2), clock=clock)
        clock.advance(100.0)
        sup.heartbeat(0, step=1)
        act = sup.decide()
        assert act.kind == "none"
        assert "min_workers" in act.detail

    def test_straggler_detection_needs_quorum(self):
        sup, _ = self.make(n=2)
        for i in range(2):
            sup.heartbeat(i, step=1, step_seconds=1.0 if i == 0 else 9.0)
        assert sup.stragglers() == []    # < 3 reporters: no verdict

    def test_straggler_rebalance_action(self):
        sup, _ = self.make(n=4)
        for i in range(4):
            sup.heartbeat(i, step=1,
                          step_seconds=5.0 if i == 3 else 1.0)
        act = sup.decide()
        assert act.kind == "rebalance"
        assert act.slow_workers == (3,)
        shares = Supervisor.rebalanced_shares(4, act.slow_workers)
        assert abs(sum(shares) - 1.0) < 1e-9
        assert shares[3] < shares[0]


class TestReplicaSupervisor:
    CFG = ReplicaSupervisorConfig(suspect_after_ticks=3, dead_after_ticks=6,
                                  max_restarts=2)

    def make(self, n=2):
        return ReplicaSupervisor(range(n), self.CFG)

    def pump(self, sup, ticks, beating=()):
        acts = []
        for t in ticks:
            for rid in beating:
                sup.heartbeat(rid, t)
            acts += sup.poll(t)
        return acts

    def test_heartbeat_loss_walks_suspect_then_dead(self):
        sup = self.make()
        self.pump(sup, range(1, 3), beating=(0, 1))
        # replica 1 goes quiet at tick 3
        acts = self.pump(sup, range(3, 6), beating=(0,))
        assert acts == []
        assert sup.state_of(1) is WorkerState.SUSPECT
        acts = self.pump(sup, range(6, 9), beating=(0,))
        assert sup.state_of(1) is WorkerState.DEAD
        assert [a.kind for a in acts] == ["restart"]
        assert acts[0].replica_id == 1
        assert sup.state_of(0) is WorkerState.HEALTHY

    def test_breaker_report_skips_suspect_grace(self):
        """A tripped circuit breaker is conclusive: DEAD immediately, no
        SUSPECT walk, restart emitted on the next poll."""
        sup = self.make()
        sup.heartbeat(0, 1)
        sup.report_failure(0, 1, "corrupt_output")
        assert sup.state_of(0) is WorkerState.DEAD
        acts = sup.poll(1)
        assert [a.kind for a in acts] == ["restart"]
        assert "corrupt_output" in acts[0].detail

    def test_restart_emitted_exactly_once(self):
        """One action per death: the router must confirm with restarted()
        before another restart can be issued."""
        sup = self.make()
        sup.report_failure(0, 1)
        assert len(sup.poll(1)) == 1
        assert sup.poll(2) == []         # pending: no re-emission
        sup.restarted(0, 3)
        assert sup.state_of(0) is WorkerState.HEALTHY
        assert sup.replicas[0].restarts == 1
        sup.report_failure(0, 4)         # a second, later death
        assert [a.kind for a in sup.poll(4)] == ["restart"]

    def test_give_up_after_restart_budget(self):
        sup = self.make()
        for tick in (1, 3, 5):           # crash loop: die, restart, die...
            sup.report_failure(0, tick)
            acts = sup.poll(tick)
            if tick < 5:
                assert [a.kind for a in acts] == ["restart"]
                sup.restarted(0, tick + 1)
        assert [a.kind for a in acts] == ["give_up"]
        assert acts[0].replica_id == 0

    def test_healthy_replicas_view(self):
        sup = self.make(3)
        sup.report_failure(1, 1)
        assert sup.healthy_replicas() == [0, 2]
