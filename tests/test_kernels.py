"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.kernels import ops, ref  # noqa: E402


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == np.float32 and False else \
        (dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16
         else dict(rtol=2e-4, atol=2e-4))


@pytest.mark.parametrize("m,n,k", [(64, 64, 64), (100, 80, 60),
                                   (256, 128, 512), (33, 257, 129)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_gemm_shapes_dtypes(m, n, k, dtype):
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((m, k)), dtype=dtype)
    b = jnp.asarray(rng.standard_normal((k, n)), dtype=dtype)
    out = ops.gemm(a, b, tile=(64, 128, 128), out_dtype=jnp.float32,
                   interpret=True)
    want = ref.gemm_ref(a, b, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               **_tol(dtype))


def test_gemm_epilogue_chain():
    rng = np.random.default_rng(1)
    a = rng.standard_normal((96, 128)).astype(np.float32)
    b = rng.standard_normal((128, 64)).astype(np.float32)
    bias = rng.standard_normal((64,)).astype(np.float32)
    res = rng.standard_normal((96, 64)).astype(np.float32)
    ep = lambda x, bb, rr: jnp.maximum(x + bb, 0.0) + rr
    kinds = ("col_vector", "full")
    out = ops.gemm(a, b, bias, res, tile=(64, 64, 128), epilogue=ep,
                   aux_kinds=kinds, interpret=True)
    want = ref.gemm_ref(a, b, bias, res, epilogue=ep, aux_kinds=kinds)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("g,m,n,k", [(2, 64, 64, 64), (5, 40, 72, 96)])
def test_batched_gemm(g, m, n, k):
    rng = np.random.default_rng(2)
    a = rng.standard_normal((g, m, k)).astype(np.float32)
    b = rng.standard_normal((g, k, n)).astype(np.float32)
    out = ops.batched_gemm(a, b, tile=(64, 64, 64), interpret=True)
    want = ref.batched_gemm_ref(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("sq,skv", [(128, 128), (100, 200), (64, 300)])
def test_flash_attention(causal, sq, skv):
    if causal and sq != skv:
        pytest.skip("causal requires square")
    rng = np.random.default_rng(3)
    q = rng.standard_normal((2, sq, 4, 32)).astype(np.float32)
    k = rng.standard_normal((2, skv, 2, 32)).astype(np.float32)
    v = rng.standard_normal((2, skv, 2, 32)).astype(np.float32)
    out = ops.attention(q, k, v, causal=causal, block_q=64, block_kv=128,
                        interpret=True)
    kr = np.repeat(k, 2, axis=2)
    vr = np.repeat(v, 2, axis=2)
    qf = np.swapaxes(q, 1, 2).reshape(8, sq, 32)
    kf = np.swapaxes(kr, 1, 2).reshape(8, skv, 32)
    vf = np.swapaxes(vr, 1, 2).reshape(8, skv, 32)
    want = ref.attention_ref(jnp.asarray(qf), jnp.asarray(kf),
                             jnp.asarray(vf), causal=causal)
    want = np.swapaxes(np.asarray(want).reshape(2, 4, sq, 32), 1, 2)
    np.testing.assert_allclose(np.asarray(out), want, rtol=2e-4, atol=2e-4)


def test_sliding_window_attention():
    rng = np.random.default_rng(4)
    q = rng.standard_normal((1, 256, 2, 32)).astype(np.float32)
    k = rng.standard_normal((1, 256, 2, 32)).astype(np.float32)
    v = rng.standard_normal((1, 256, 2, 32)).astype(np.float32)
    out = ops.attention(q, k, v, causal=True, window=64, interpret=True)
    qf = np.swapaxes(q, 1, 2).reshape(2, 256, 32)
    kf = np.swapaxes(k, 1, 2).reshape(2, 256, 32)
    vf = np.swapaxes(v, 1, 2).reshape(2, 256, 32)
    want = ref.attention_ref(jnp.asarray(qf), jnp.asarray(kf),
                             jnp.asarray(vf), causal=True, window=64)
    want = np.swapaxes(np.asarray(want).reshape(1, 2, 256, 32), 1, 2)
    np.testing.assert_allclose(np.asarray(out), want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("rows,d", [(64, 128), (100, 512), (300, 256)])
def test_rmsnorm_layernorm_softmax(rows, d):
    rng = np.random.default_rng(5)
    x = rng.standard_normal((rows, d)).astype(np.float32)
    g = rng.standard_normal((d,)).astype(np.float32)
    b = rng.standard_normal((d,)).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(ops.rmsnorm(x, g, block_rows=64, interpret=True)),
        np.asarray(ref.rmsnorm_ref(x, g)), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(ops.layernorm(x, g, b, block_rows=64, interpret=True)),
        np.asarray(ref.layernorm_ref(x, g, b)), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(ops.softmax(x, block_rows=64, interpret=True)),
        np.asarray(ref.softmax_ref(x)), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("chunk", [32, 64, 128])
@pytest.mark.parametrize("t", [128, 200])
def test_ssd_scan_vs_sequential(chunk, t):
    rng = np.random.default_rng(6)
    B, H, P, N = 2, 2, 16, 16
    x = (rng.standard_normal((B, t, H, P)) * 0.4).astype(np.float32)
    dt = rng.uniform(0.001, 0.1, (B, t, H)).astype(np.float32)
    a = (-rng.uniform(0.5, 2.0, (H,))).astype(np.float32)
    bm = (rng.standard_normal((B, t, N)) * 0.3).astype(np.float32)
    cm = (rng.standard_normal((B, t, N)) * 0.3).astype(np.float32)
    y = ops.ssd(x, dt, a, bm, cm, chunk=chunk, interpret=True)
    xbar = x * dt[..., None]
    da = dt * a[None, None]
    xf = np.swapaxes(xbar, 1, 2).reshape(B * H, t, P)
    daf = np.swapaxes(da, 1, 2).reshape(B * H, t)
    bf = np.repeat(bm[:, None], H, 1).reshape(B * H, t, N)
    cf = np.repeat(cm[:, None], H, 1).reshape(B * H, t, N)
    want = ref.ssd_scan_ref(jnp.asarray(xf), jnp.asarray(daf),
                            jnp.asarray(bf), jnp.asarray(cf))
    want = np.swapaxes(np.asarray(want).reshape(B, H, t, P), 1, 2)
    np.testing.assert_allclose(np.asarray(y), want, rtol=1e-3, atol=1e-4)


def test_eltwise_row_map():
    rng = np.random.default_rng(7)
    x = rng.standard_normal((100, 64)).astype(np.float32)
    out = ops.eltwise(x, jnp.tanh, block_rows=32, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.tanh(x),
                               rtol=1e-5, atol=1e-5)
