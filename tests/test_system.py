"""End-to-end behaviour tests for the paper's system."""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core.agent import VARIANTS, run_variant  # noqa: E402
from repro.core.integrity import review_logs  # noqa: E402
from repro.core.problems import all_problems  # noqa: E402
from repro.core.schedule import (SchedulePolicy, replay,  # noqa: E402
                                 summarize)
from repro.configs import SMOKE_SHAPES, get_arch  # noqa: E402
from repro.launch.mesh import make_smoke_mesh  # noqa: E402
from repro.launch.specs import input_specs  # noqa: E402
from repro.models.model import build_model  # noqa: E402
from repro.sharding.rules import (batch_shardings,  # noqa: E402
                                  params_shardings)
from repro.train.step import init_state, make_train_step  # noqa: E402
from repro.optim.adamw import AdamWState  # noqa: E402
from repro.train.step import TrainState  # noqa: E402
from repro.sharding.rules import replicated  # noqa: E402


def test_paper_pipeline_end_to_end():
    """DSL agent -> integrity filter -> scheduler on a problem subset:
    the paper's qualitative claims hold."""
    probs = [all_problems()[p] for p in
             ("L1/1", "L1/23", "L2/76", "L2/88", "L3/44")]
    raw = run_variant(VARIANTS["mi_raw"], probs, capability="mini")
    dsl = run_variant(VARIANTS["orch_dsl"], probs, capability="mini")
    review_logs(raw)
    review_logs(dsl)
    s_raw, s_dsl = summarize(raw), summarize(dsl)
    # claim 1: the DSL turns a regression into a speedup
    assert s_dsl["geomean"] > 1.0 > s_raw["geomean"]
    # claim 2: DSL uses fewer tokens under the same attempt budget
    assert s_dsl["total_tokens"] < s_raw["total_tokens"]
    # claim 3: scheduling saves tokens at high retention
    rep = replay(dsl, SchedulePolicy(epsilon=1.0, window=8))
    assert rep.token_savings > 0.05
    assert rep.geomean_retention > 0.8


def test_train_step_lowering_on_smoke_mesh():
    """The dry-run path (shardings + lower + compile) works end-to-end on
    the 1-device CPU mesh with the production axis names."""
    cfg = get_arch("qwen2-0.5b").reduced()
    model = build_model(cfg)
    mesh = make_smoke_mesh()
    state_abs = jax.eval_shape(
        lambda: init_state(model, jax.random.PRNGKey(0)))
    state_sh = TrainState(
        params=params_shardings(state_abs.params, mesh),
        opt=AdamWState(step=replicated(mesh),
                       mu=params_shardings(state_abs.opt.mu, mesh),
                       nu=params_shardings(state_abs.opt.nu, mesh)))
    shape = SMOKE_SHAPES["train_4k"]
    batch_abs = input_specs(cfg, shape)
    batch_sh = batch_shardings(batch_abs, mesh)
    step = make_train_step(model)
    with mesh:
        lowered = jax.jit(step, in_shardings=(state_sh, batch_sh),
                          out_shardings=(state_sh, None)).lower(
                              state_abs, batch_abs)
        compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # older jax: one dict per device
        cost = cost[0]
    assert cost.get("flops", 0) > 0
