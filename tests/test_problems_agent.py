"""Problem suite + MANTIS agent + integrity + scheduler integration tests."""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core.agent import (Agent, AgentConfig, CostModel, RunLog,
                              VARIANTS, run_variant, roi, triage)
from repro.core.agent.policies import Hypothesis
from repro.core.dsl import compile_dsl, validate_dsl
from repro.core.integrity import inflation, review_logs
from repro.core.problems import (Solution, all_problems, degenerate_problem,
                                 get_problem, problem_ids)
from repro.core.schedule import (SchedulePolicy, best_policy, geomean, replay,
                                 summarize, sweep)

PROBS = all_problems()


class TestSuite:
    def test_59_problems_match_paper_ids(self):
        ids = problem_ids()
        assert len(ids) == 59
        assert sum(1 for i in ids if i.startswith("L1")) == 31
        assert sum(1 for i in ids if i.startswith("L2")) == 20
        assert sum(1 for i in ids if i.startswith("L3")) == 8

    def test_references_execute_and_finite(self):
        rng = np.random.default_rng(0)
        for pid in ("L1/1", "L1/23", "L2/76", "L2/88", "L3/44", "L3/48"):
            p = PROBS[pid]
            out = p.reference(*p.make_inputs(rng))
            assert np.all(np.isfinite(np.asarray(out, dtype=np.float32)))

    def test_degenerate_problem_is_identically_zero(self):
        p = degenerate_problem()
        rng = np.random.default_rng(1)
        out = np.asarray(p.reference(*p.make_inputs(rng)))
        assert np.allclose(out, 0.0)
        assert p.degenerate

    def test_all_templates_validate(self):
        for pid, p in PROBS.items():
            for seg, src in p.dsl_template.items():
                assert validate_dsl(src) == [], (pid, seg)

    def test_template_kernels_match_reference(self):
        """Compile the known-good DSL plan and execute it vs the problem
        reference at reduced scale (real end-to-end correctness)."""
        rng = np.random.default_rng(2)
        # L1/36 rmsnorm
        p = PROBS["L1/36"]
        x, g = p.make_inputs(rng)
        k = compile_dsl(p.dsl_template["norm"], "pallas")
        np.testing.assert_allclose(np.asarray(k(x, g)),
                                   np.asarray(p.reference(x, g)),
                                   rtol=1e-4, atol=1e-4)
        # L2/76 gemm+bias+relu (single fused kernel)
        p = PROBS["L2/76"]
        a, b, bias = p.make_inputs(rng)
        k = compile_dsl(p.dsl_template["gemm"], "pallas")
        out = np.asarray(k(a, b, bias), dtype=np.float32)
        want = np.asarray(p.reference(a, b, bias))
        np.testing.assert_allclose(out, want, rtol=5e-2, atol=5e-2)


class TestAgent:
    def test_deterministic_runs(self):
        p = get_problem("L2/76")
        l1 = run_variant(VARIANTS["orch_dsl"], [p], capability="mid", seed=3)
        l2 = run_variant(VARIANTS["orch_dsl"], [p], capability="mid", seed=3)
        assert [a.speedup for a in l1[0].attempts] == \
            [a.speedup for a in l2[0].attempts]

    def test_budget_respected(self):
        p = get_problem("L1/1")
        for v in VARIANTS.values():
            logs = run_variant(v, [p], capability="mini", seed=0)
            assert logs[0].n_attempts <= 40

    def test_dsl_beats_raw_filtered(self):
        probs = [PROBS[p] for p in ("L1/1", "L1/9", "L2/76", "L2/29",
                                    "L3/44")]
        raw = run_variant(VARIANTS["mi_raw"], probs, capability="mini")
        dsl = run_variant(VARIANTS["mi_dsl"], probs, capability="mini")
        review_logs(raw)
        review_logs(dsl)
        g_raw = summarize(raw)["geomean"]
        g_dsl = summarize(dsl)["geomean"]
        assert g_dsl > g_raw * 1.5

    def test_sol_guided_beats_unguided_dsl(self):
        probs = [PROBS[p] for p in ("L1/1", "L1/97", "L2/88", "L3/48",
                                    "L2/37")]
        mi = run_variant(VARIANTS["mi_dsl"], probs, capability="mini")
        orch = run_variant(VARIANTS["orch_dsl"], probs, capability="mini")
        review_logs(mi)
        review_logs(orch)
        assert summarize(orch)["geomean"] >= summarize(mi)["geomean"] * 0.95

    def test_legit_solutions_respect_sol_ceiling(self):
        p = get_problem("L1/1")
        logs = run_variant(VARIANTS["orch_dsl"], [p], capability="max")
        for a in logs[0].attempts:
            if a.ok and not a.flags or (a.ok and a.flags ==
                                        ["reduced_precision"]):
                assert a.runtime_s >= 0.9 * logs[0].t_sol_ceiling

    def test_roi_gap_exponent(self):
        h_ambitious = Hypothesis(Solution(), "big", est_speedup=3.0,
                                 risk_impl=2.0, risk_perf=2.0)
        h_safe = Hypothesis(Solution(), "small", est_speedup=1.2,
                            risk_impl=1.0, risk_perf=1.0)
        # near SOL (g=1): safe wins; far from SOL (g=500): ambitious wins
        near = triage([h_ambitious, h_safe], gap=1.0, top_n=1)[0]
        far = triage([h_ambitious, h_safe], gap=500.0, top_n=1)[0]
        assert near.description == "small"
        assert far.description == "big"


class TestIntegrity:
    def _logs(self, cap="max"):
        probs = [PROBS[p] for p in ("L1/1", "L1/9", "L2/76", "L2/29",
                                    "L2/88", "L3/44")]
        return run_variant(VARIANTS["mi_dsl"], probs, capability=cap, seed=1)

    def test_labels_partition_attempts(self):
        logs = self._logs()
        counts = review_logs(logs)
        total = sum(counts.values())
        assert total == sum(l.n_attempts for l in logs)

    def test_inflation_monotone(self):
        logs = self._logs()
        inf = inflation(logs)
        assert inf.filtered_geomean <= inf.allow_pytorch_only + 1e-9
        assert inf.allow_pytorch_only <= inf.allow_gaming + 1e-9
        assert inf.allow_gaming <= inf.unfiltered + 1e-9

    def test_gaming_attempts_never_accepted(self):
        logs = self._logs()
        review_logs(logs)
        for log in logs:
            for a in log.attempts:
                if a.flags and any(f.startswith("skip:") or f in
                                   ("constant_output", "input_exploit")
                                   for f in a.flags):
                    assert a.label not in ("no_issues", "minor")


class TestScheduler:
    def test_sweep_and_best_policy(self):
        probs = [PROBS[p] for p in problem_ids()[:12]]
        logs = run_variant(VARIANTS["orch_dsl"], probs, capability="mid")
        review_logs(logs)
        results = sweep(logs)
        bp = best_policy(results, min_retention=0.9)
        assert bp is not None
        assert bp.token_savings > 0
        assert bp.geomean_retention >= 0.9

    def test_savings_increase_with_aggressiveness(self):
        probs = [PROBS[p] for p in problem_ids()[:8]]
        logs = run_variant(VARIANTS["orch_dsl"], probs, capability="mid")
        review_logs(logs)
        tight = replay(logs, SchedulePolicy(0.25, 0))
        loose = replay(logs, SchedulePolicy(3.0, 4))
        assert loose.token_savings >= tight.token_savings - 1e-9
