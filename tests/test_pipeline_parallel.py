"""Pipeline-parallelism schedule test — runs in a subprocess with 4 forced
host devices (the main pytest process is pinned to 1 device)."""

import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
import numpy as np
from repro.sharding.pipeline_parallel import pipeline_apply

mesh = jax.make_mesh((4,), ("stage",))
S, M, B, D = 4, 8, 2, 16

def stage_fn(w, x):
    return jnp.tanh(x @ w)

rng = np.random.default_rng(0)
ws = jnp.asarray(rng.standard_normal((S, D, D)) * 0.5, jnp.float32)
micro = jnp.asarray(rng.standard_normal((M, B, D)), jnp.float32)

fn = pipeline_apply(stage_fn, mesh, "stage")
with mesh:
    out = jax.jit(fn)(ws, micro)

# reference: every microbatch through all stages sequentially
want = np.asarray(micro)
for s in range(S):
    want = np.tanh(want @ np.asarray(ws[s]))
err = np.abs(np.asarray(out) - want).max()
assert err < 1e-5, err
print("PP_OK", err)
"""


def test_pipeline_parallel_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=300,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert "PP_OK" in res.stdout, res.stdout + res.stderr
