"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + no NaNs (the assignment's smoke requirement), plus
decode-vs-forward consistency."""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCHS, get_arch  # noqa: E402
from repro.data.pipeline import DataConfig, TokenSource  # noqa: E402
from repro.models.model import build_model  # noqa: E402
from repro.optim.adamw import AdamWConfig  # noqa: E402
from repro.train.step import init_state, make_train_step  # noqa: E402

ARCH_NAMES = sorted(ARCHS.keys())


def _batch(cfg, rng, b=2, s=32):
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}
    batch["labels"] = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, cfg.encoder_seq, cfg.d_model)),
            jnp.bfloat16)
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.vision_patches, cfg.d_model)),
            jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_forward_and_train_step(arch):
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = _batch(cfg, rng)

    logits, aux = model.forward(params, batch)
    assert logits.shape == (2, 32, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))

    state = init_state(model, jax.random.PRNGKey(0))
    step = make_train_step(model, AdamWConfig(warmup_steps=1, total_steps=10))
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    delta = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                         state.params, new_state.params)
    assert max(jax.tree.leaves(delta)) > 0


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "mamba2-1.3b",
                                  "mixtral-8x7b"])
def test_decode_consistent_with_forward(arch):
    """Teacher-forced decode step-by-step must reproduce the parallel
    forward's next-token logits (cache correctness)."""
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    b, s = 2, 12
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)

    full_logits, _ = model.forward(params, {"tokens": tokens})
    cache = model.init_cache(b, max_len=32)
    step_logits = []
    for t in range(s):
        lg, cache = model.decode_step(params, cache, tokens[:, t:t + 1])
        step_logits.append(np.asarray(lg[:, 0], np.float32))
    step_logits = np.stack(step_logits, axis=1)
    np.testing.assert_allclose(
        step_logits, np.asarray(full_logits, np.float32),
        rtol=0.15, atol=0.25)  # bf16 + chunked-vs-sequential recurrence drift


def test_sliding_window_cache_matches_full_for_short_seq():
    cfg = get_arch("mixtral-8x7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    rng = np.random.default_rng(2)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 8)), jnp.int32)
    full_logits, _ = model.forward(params, {"tokens": tokens})
    cache = model.init_cache(1, max_len=cfg.sliding_window)
    outs = []
    for t in range(8):
        lg, cache = model.decode_step(params, cache, tokens[:, t:t + 1])
        outs.append(np.asarray(lg[:, 0], np.float32))
    np.testing.assert_allclose(np.stack(outs, 1),
                               np.asarray(full_logits, np.float32),
                               rtol=0.1, atol=0.15)


def test_param_counts_match_published_sizes():
    expect = {
        "mistral-nemo-12b": 12.2e9,
        "command-r-plus-104b": 104e9,
        "qwen2-0.5b": 0.49e9,
        "mixtral-8x7b": 46.7e9,
        "mamba2-1.3b": 1.3e9,
        "llama-3.2-vision-90b": 90e9,
    }
    for name, n in expect.items():
        got = get_arch(name).param_count()
        assert abs(got - n) / n < 0.1, (name, got, n)


def test_data_pipeline_determinism_and_host_sharding():
    cfg = DataConfig(global_batch=8, seq_len=16, vocab_size=100,
                     num_hosts=4, host_id=2, seed=7)
    src = TokenSource(cfg)
    b1, b2 = src.batch_at(5), src.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (2, 16)          # host shard
    other = TokenSource(DataConfig(global_batch=8, seq_len=16,
                                   vocab_size=100, num_hosts=4, host_id=3,
                                   seed=7)).batch_at(5)
    assert not np.array_equal(b1["tokens"], other["tokens"])
    # labels are next-token shifted
    full = TokenSource(cfg)
    b = full.batch_at(0)
    assert b["tokens"].shape == b["labels"].shape


def test_ssd_impl_variants_agree():
    """All three SSD implementations compute the same recurrence."""
    import jax.numpy as jnp
    from repro.models.ssm import _ssd_chunk_scan, _ssd_chunked
    rng = np.random.default_rng(3)
    B, T, H, P, N = 2, 64, 2, 8, 8
    xbar = jnp.asarray(rng.standard_normal((B, T, H, P)) * 0.3, jnp.float32)
    da = jnp.asarray(-rng.uniform(0.01, 0.2, (B, T, H)), jnp.float32)
    bm = jnp.asarray(rng.standard_normal((B, T, N)) * 0.3, jnp.float32)
    cm = jnp.asarray(rng.standard_normal((B, T, N)) * 0.3, jnp.float32)
    ref = np.asarray(_ssd_chunked(xbar, da, bm, cm, 32), np.float32)
    scan = np.asarray(_ssd_chunk_scan(xbar, da, bm, cm, 32), np.float32)
    bf16 = np.asarray(_ssd_chunked(xbar, da, bm, cm, 32,
                                   decay_dtype=jnp.bfloat16), np.float32)
    np.testing.assert_allclose(scan, ref, rtol=2e-2, atol=1e-2)
    np.testing.assert_allclose(bf16, ref, rtol=2e-2, atol=1e-2)


def test_prefill_last_only_shape():
    import dataclasses
    from repro.train.step import make_prefill_step
    cfg = dataclasses.replace(get_arch("qwen2-0.5b").reduced(),
                              prefill_last_only=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)}
    logits = make_prefill_step(model)(params, batch)
    assert logits.shape == (2, 1, cfg.padded_vocab)


def test_dryrun_config_overrides():
    from repro.launch.dryrun import _apply_overrides
    cfg = get_arch("mixtral-8x7b")
    out = _apply_overrides(cfg, ("remat_policy=dots", "capacity_factor=1.0",
                                 "cast_params_once=true"))
    assert out.remat_policy == "dots"
    assert out.capacity_factor == 1.0
    assert out.cast_params_once is True
    import pytest as _pytest
    with _pytest.raises(AttributeError):
        _apply_overrides(cfg, ("not_a_knob=1",))
