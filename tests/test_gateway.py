"""Fault-tolerant serving front door: router placement, admission and
backpressure, circuit breakers, fault drills (kill / heartbeat loss /
output corruption), zero-divergence re-routing, warm handoff, and the
aiohttp HTTP + WebSocket gateway over real sockets."""

import asyncio

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.configs import get_arch  # noqa: E402
from repro.core.sol.fleet import (FleetCapacityModel,  # noqa: E402
                                  ReplicaLoad)
from repro.ft.supervisor import (ReplicaSupervisorConfig,  # noqa: E402
                                 WorkerState)
from repro.models.model import build_model  # noqa: E402
from repro.serve import (FaultInjector, ReplicaState, Request,  # noqa: E402
                         RouterRejected, ServeEngine, SOLCapacityModel,
                         TokenBucket, build_replicated_router)

_MODEL = None


def tiny_model():
    global _MODEL
    if _MODEL is None:
        cfg = get_arch("qwen2-0.5b").reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        _MODEL = (model, params)
    return _MODEL


def make_router(replicas=2, **kw):
    model, params = tiny_model()
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_len", 32)
    kw.setdefault("chunk_size", 4)
    return build_replicated_router(model, params, replicas=replicas, **kw)


def prompts(n=4, length=5, seed=0):
    model, _ = tiny_model()
    rng = np.random.default_rng(seed)
    return [list(map(int, rng.integers(1, model.cfg.vocab_size, length)))
            for _ in range(n)]


def baseline_tokens(prompt, max_new=4):
    """Single-engine greedy reference for divergence checks."""
    model, params = tiny_model()
    req = Request(rid=0, prompt=list(prompt), max_new_tokens=max_new)
    ServeEngine(model, params, max_batch=1, max_len=32,
                chunk_size=4).run([req])
    return req.out_tokens


class TestFleetCapacityModel:
    def make(self, **kw):
        model, _ = tiny_model()
        return FleetCapacityModel(SOLCapacityModel(model.cfg), **kw)

    def load(self, rid=0, free=2, slots=2, queue=0, decode=(), backlog=0):
        return ReplicaLoad(replica_id=rid, free_slots=free, num_slots=slots,
                           queue_depth=queue, decode_positions=decode,
                           prefill_backlog=backlog)

    def test_choose_prefers_idle_replica(self):
        fleet = self.make()
        busy = self.load(rid=0, free=0, queue=3, decode=(8, 8),
                         backlog=64)
        idle = self.load(rid=1)
        assert fleet.choose([busy, idle], prompt_tokens=8) == 1

    def test_choose_skips_full_queues(self):
        fleet = self.make(max_queue_per_replica=2)
        full = self.load(rid=0, free=0, queue=2)
        open_ = self.load(rid=1, free=0, queue=1, decode=(4,))
        assert fleet.choose([full, open_], prompt_tokens=4) == 1
        assert fleet.choose([full], prompt_tokens=4) is None

    def test_verdict_saturated_prices_retry_after(self):
        fleet = self.make(max_queue_per_replica=2)
        loads = [self.load(rid=i, free=0, queue=2, decode=(8, 8))
                 for i in range(2)]
        v = fleet.verdict(loads, prompt_tokens=4, itl_budget_s=10.0)
        assert not v.admit
        assert v.retry_after_s > 0

    def test_verdict_admits_open_fleet(self):
        v = self.make().verdict([self.load()], prompt_tokens=4,
                                itl_budget_s=10.0)
        assert v.admit

    def test_no_replicas_is_rejected(self):
        v = self.make().verdict([], prompt_tokens=4, itl_budget_s=10.0)
        assert not v.admit and v.reason == "no_replicas"


class TestAdmission:
    def test_token_bucket_refills_at_rate(self):
        b = TokenBucket(rate=2.0, burst=2.0)
        assert b.try_take(0.0) == 0.0
        assert b.try_take(0.0) == 0.0
        wait = b.try_take(0.0)           # burst exhausted
        assert wait == pytest.approx(0.5)
        assert b.try_take(1.0) == 0.0    # refilled

    def test_rate_limit_rejects_with_retry_after(self):
        now = [0.0]
        router = make_router(rate_limits={"batch": (1.0, 1.0)},
                             clock=lambda: now[0])
        ps = prompts(3)
        router.submit(ps[0], max_new_tokens=2)
        with pytest.raises(RouterRejected) as exc:
            router.submit(ps[1], max_new_tokens=2)
        assert exc.value.reason == "rate_limited"
        assert exc.value.retry_after_s > 0
        # interactive class has no bucket configured -> unlimited
        router.submit(ps[1], max_new_tokens=2, slo="interactive")
        now[0] = 2.0                     # bucket refilled
        router.submit(ps[2], max_new_tokens=2)
        assert router.counters["rejected_rate_limited"] == 1

    def test_backpressure_when_fleet_saturated(self):
        router = make_router(replicas=1, max_batch=1,
                             max_queue_per_replica=1)
        ps = prompts(3)
        router.submit(ps[0], max_new_tokens=8)
        router.pump()                            # admitted into the slot
        router.submit(ps[1], max_new_tokens=8)   # fills the queue
        with pytest.raises(RouterRejected) as exc:
            router.submit(ps[2], max_new_tokens=2)
        assert exc.value.reason == "queue_full"
        assert exc.value.retry_after_s > 0
        assert router.counters["rejected_saturated"] == 1

    def test_placement_spreads_by_capacity(self):
        router = make_router(replicas=2)
        t1, t2 = (router.submit(p, max_new_tokens=2)
                  for p in prompts(2))
        assert {t1.replica_id, t2.replica_id} == {0, 1}


class TestFaultDrills:
    def run_fleet(self, router, tickets):
        router.run_until_complete(tickets, max_ticks=2000)
        return tickets

    def submit_all(self, router, ps, max_new=4):
        return [router.submit(p, max_new_tokens=max_new) for p in ps]

    def test_kill_mid_stream_reroutes_zero_divergence(self):
        """The acceptance drill: replica killed mid-generation; its
        tickets replay on the survivor and finish with tokens identical
        to a fault-free single engine."""
        inj = FaultInjector()
        router = make_router(injector=inj)
        ps = prompts(4)
        tickets = self.submit_all(router, ps)
        inj.kill(0, at_tick=3)
        self.run_fleet(router, tickets)
        assert all(t.status == "done" for t in tickets)
        assert router.counters["rerouted_tickets"] > 0
        for t, p in zip(tickets, ps):
            assert t.tokens == baseline_tokens(p)
        victims = [t for t in tickets if t.reroutes > 0]
        assert victims and all(t.replica_id == 1 for t in victims)
        assert router.counters["divergence_failures"] == 0

    def test_breaker_trips_after_threshold(self):
        inj = FaultInjector()
        router = make_router(injector=inj, breaker_threshold=3)
        tickets = self.submit_all(router, prompts(2))
        inj.kill(0, at_tick=1)
        for _ in range(2):
            router.pump()
        r0 = router.replicas[0]
        assert r0.state is ReplicaState.RUNNING    # not yet tripped
        assert r0.breaker.consecutive_failures == 2
        router.pump()                              # third strike
        assert r0.state is not ReplicaState.RUNNING or r0.generation == 1
        assert router.counters["step_failures"] >= 3
        self.run_fleet(router, tickets)
        assert all(t.status == "done" for t in tickets)

    def test_supervised_restart_and_readmission(self):
        inj = FaultInjector()
        router = make_router(injector=inj)
        tickets = self.submit_all(router, prompts(4))
        inj.kill(0, at_tick=2)
        self.run_fleet(router, tickets)
        r0 = router.replicas[0]
        assert r0.state is ReplicaState.RUNNING
        assert r0.generation == 1
        assert not r0.breaker.open
        assert router.counters["replica_restarts"] == 1
        assert len(router.incidents) == 1
        assert router.supervisor.state_of(0) is WorkerState.HEALTHY
        # readmitted: new submissions can land on the restarted replica
        extra = [router.submit(p, max_new_tokens=2)
                 for p in prompts(4, seed=7)]
        assert 0 in {t.replica_id for t in extra}
        self.run_fleet(router, extra)
        assert all(t.status == "done" for t in extra)

    def test_heartbeat_loss_walks_suspect_to_dead(self):
        """A partitioned replica never fails a step — the supervisor's
        missed-heartbeat walk must get it restarted anyway."""
        cfg = ReplicaSupervisorConfig(suspect_after_ticks=2,
                                      dead_after_ticks=4)
        inj = FaultInjector()
        router = make_router(injector=inj, supervisor_cfg=cfg)
        tickets = self.submit_all(router, prompts(2))
        inj.delay_heartbeats(0, from_tick=1, until_tick=50)
        for _ in range(3):
            router.pump()
        assert router.supervisor.state_of(0) is WorkerState.SUSPECT
        while not router.incidents and router.tick < 50:
            router.pump()
        assert router.incidents[0]["replica_id"] == 0
        assert router.replicas[0].generation == 1
        self.run_fleet(router, tickets)
        assert all(t.status == "done" for t in tickets)

    def test_corrupt_output_detected_and_survived(self):
        """Silently corrupted tokens must be caught by output validation
        (never delivered), charged to the breaker, and recovered from."""
        inj = FaultInjector()
        router = make_router(injector=inj, breaker_threshold=1)
        ps = prompts(4)
        tickets = self.submit_all(router, ps)
        inj.corrupt_output(0, at_tick=2, n_ticks=1)
        self.run_fleet(router, tickets)
        assert all(t.status == "done" for t in tickets)
        vocab = tiny_model()[0].cfg.vocab_size
        assert all(0 <= tok < vocab for t in tickets for tok in t.tokens)
        for t, p in zip(tickets, ps):
            assert t.tokens == baseline_tokens(p)
        assert router.counters["step_failures"] >= 1

    def test_warm_handoff_shared_prefix_cache(self):
        """The restarted engine re-adopts the fleet-shared prefix cache:
        its first shared-prefix request is a hit, not a cold prefill."""
        inj = FaultInjector()
        router = make_router(injector=inj)
        shared = prompts(1, length=8)[0]
        tails = prompts(4, length=3, seed=3)
        tickets = self.submit_all(router, [shared + t for t in tails])
        inj.kill(0, at_tick=4)
        self.run_fleet(router, tickets)
        r0, r1 = router.replicas[0], router.replicas[1]
        assert r0.generation == 1
        assert r0.engine.prefix_cache is r1.engine.prefix_cache
        assert len(r0.engine.prefix_cache) > 0
        before = r0.engine.metrics["prefix_hits"]
        extra = router.submit(shared + prompts(1, length=3, seed=9)[0],
                              max_new_tokens=2)
        while extra.status not in ("done", "failed"):
            router.pump()
        hit_engine = router.replicas[extra.replica_id].engine
        assert hit_engine.metrics["prefix_hits"] > (
            before if extra.replica_id == 0 else 0) - 1

    def test_crash_loop_gives_up_and_fails_fast(self):
        """A replica that dies into the same fault on every restart must
        be retired after max_restarts, not bounced forever."""
        cfg = ReplicaSupervisorConfig(max_restarts=1)

        class StickyInjector(FaultInjector):
            def revive(self, replica_id, tick=0):
                super().revive(replica_id, tick)
                self.kill(replica_id, tick + 1)    # same fault, next tick

        inj = StickyInjector()
        router = make_router(injector=inj, supervisor_cfg=cfg)
        tickets = self.submit_all(router, prompts(4))
        inj.kill(0, at_tick=2)
        self.run_fleet(router, tickets)
        assert all(t.status == "done" for t in tickets)
        assert router.replicas[0].generation == 1    # budget spent
        # new work lands on the restarted replica -> it dies into the
        # same fault -> the supervisor gives up instead of bouncing it
        extra = self.submit_all(router, prompts(4, seed=5))
        self.run_fleet(router, extra)
        assert all(t.status == "done" for t in extra)
        assert router.replicas[0].state is ReplicaState.RETIRED
        assert router.healthz()["status"] == "degraded"

    def test_deadline_exceeded_fails_retryable(self):
        router = make_router()
        t = router.submit(prompts(1)[0], max_new_tokens=20,
                          deadline_steps=2)
        while t.status not in ("done", "failed") and router.tick < 100:
            router.pump()
        assert t.status == "failed"
        assert t.error == "deadline_exceeded"
        assert t.retryable

    def test_cancel_releases_capacity(self):
        router = make_router(replicas=1, max_batch=1,
                             max_queue_per_replica=1)
        ps = prompts(3)
        t1 = router.submit(ps[0], max_new_tokens=8)
        router.pump()                                 # t1 takes the slot
        t2 = router.submit(ps[1], max_new_tokens=8)   # queued
        router.cancel(t1)
        assert t1.status == "failed" and t1.error == "cancelled"
        router.pump()                # freed slot admits the queued request
        t3 = router.submit(ps[2], max_new_tokens=2)
        router.run_until_complete([t2, t3], max_ticks=500)
        assert t2.status == "done" and t3.status == "done"
        eng = router.replicas[0].engine
        assert eng.metrics["cancelled"] == 1


class TestEngineDeadlines:
    """Satellite: the scheduler slot-leak fix — abandoned requests release
    their slots at the occupancy deadline and are counted."""

    def test_expired_request_releases_slot(self):
        model, params = tiny_model()
        eng = ServeEngine(model, params, max_batch=1, max_len=32,
                          chunk_size=4, request_timeout_steps=8)
        stuck = Request(rid=0, prompt=prompts(1)[0], max_new_tokens=25)
        ok = Request(rid=1, prompt=prompts(1, seed=1)[0], max_new_tokens=2)
        eng.submit(stuck)
        eng.submit(ok)
        for _ in range(100):
            if ok.done:
                break
            eng.step()
        assert stuck.timed_out and not stuck.done
        assert ok.done                   # reclaimed slot served the queue
        assert eng.metrics["timed_out"] == 1
        assert eng.telemetry.summary()["timed_out"] == 1

    def test_per_request_deadline_overrides_engine_default(self):
        model, params = tiny_model()
        eng = ServeEngine(model, params, max_batch=2, max_len=32,
                          chunk_size=4)
        tight = Request(rid=0, prompt=prompts(1)[0], max_new_tokens=25,
                        deadline_steps=2)
        loose = Request(rid=1, prompt=prompts(1, seed=1)[0],
                        max_new_tokens=3)
        eng.run([tight, loose], max_steps=200)
        assert tight.timed_out and not tight.done
        assert loose.done and not loose.timed_out

    def test_cancel_queued_and_placed(self):
        model, params = tiny_model()
        eng = ServeEngine(model, params, max_batch=1, max_len=32,
                          chunk_size=4)
        a = Request(rid=0, prompt=prompts(1)[0], max_new_tokens=8)
        b = Request(rid=1, prompt=prompts(1, seed=1)[0], max_new_tokens=8)
        eng.submit(a)
        eng.submit(b)                    # still queued (1 slot)
        eng.step()
        assert eng.cancel(1)             # from the scheduler queue
        assert eng.cancel(0)             # from its slot
        assert not eng.cancel(99)
        assert eng.metrics["cancelled"] == 2
        assert not eng.has_work()


# ---------------------------------------------------------------------------
# HTTP / WebSocket gateway over real sockets
# ---------------------------------------------------------------------------

aiohttp = pytest.importorskip("aiohttp")

from repro.serve.gateway import start_gateway  # noqa: E402


def gateway_session(test):
    """Run ``await test(base_url, session, router, injector)`` against a
    live gateway on an ephemeral port."""
    async def main():
        inj = FaultInjector()
        router = make_router(injector=inj,
                             rate_limits={"interactive": (0.1, 2.0)})
        runner, port = await start_gateway(router, port=0)
        base = f"http://127.0.0.1:{port}"
        try:
            async with aiohttp.ClientSession() as sess:
                await test(base, sess, router, inj)
        finally:
            await runner.cleanup()
    asyncio.run(main())


class TestGatewayHTTP:
    def test_healthz_and_metrics(self):
        async def t(base, sess, router, inj):
            async with sess.get(base + "/healthz") as resp:
                assert resp.status == 200
                body = await resp.json()
                assert body["status"] == "ok"
                assert len(body["replicas"]) == 2
            async with sess.get(base + "/metrics.json") as resp:
                assert resp.status == 200
                body = await resp.json()
                assert {"requests", "counters", "ttft_steps_p95",
                        "timed_out", "drift"} <= set(body)
            async with sess.get(base + "/metrics") as resp:
                assert resp.status == 200
                assert resp.content_type == "text/plain"
                text = await resp.text()
                assert "# TYPE repro_requests_total counter" in text
                assert "# TYPE repro_ttft_seconds histogram" in text
                assert "repro_fleet_requests" in text
        gateway_session(t)

    def test_generate_roundtrip_matches_engine(self):
        prompt = prompts(1)[0]
        expected = baseline_tokens(prompt)

        async def t(base, sess, router, inj):
            async with sess.post(base + "/v1/generate",
                                 json={"prompt": prompt,
                                       "max_new_tokens": 4}) as resp:
                assert resp.status == 200
                body = await resp.json()
            assert body["status"] == "done"
            assert body["tokens"] == expected
        gateway_session(t)

    def test_generate_rejects_bad_prompt(self):
        async def t(base, sess, router, inj):
            for bad in ({}, {"prompt": "text"}, {"prompt": []}):
                async with sess.post(base + "/v1/generate",
                                     json=bad) as resp:
                    assert resp.status == 400
        gateway_session(t)

    def test_rate_limited_429_with_retry_after(self):
        prompt = prompts(1)[0]

        async def t(base, sess, router, inj):
            codes = []
            for _ in range(4):           # burst of 2 then rejections
                async with sess.post(
                        base + "/v1/generate",
                        json={"prompt": prompt, "max_new_tokens": 1,
                              "slo": "interactive"}) as resp:
                    codes.append(resp.status)
                    if resp.status == 429:
                        assert float(resp.headers["Retry-After"]) > 0
                        body = await resp.json()
                        assert body["error"] == "rate_limited"
            assert 429 in codes and 200 in codes
        gateway_session(t)

    def test_ws_stream_delivers_tokens_in_order(self):
        prompt = prompts(1)[0]
        expected = baseline_tokens(prompt)

        async def t(base, sess, router, inj):
            async with sess.ws_connect(base + "/v1/stream") as ws:
                await ws.send_json({"prompt": prompt, "max_new_tokens": 4})
                toks, done = [], None
                async for msg in ws:
                    data = msg.json()
                    if data.get("done"):
                        done = data
                        break
                    assert data["index"] == len(toks)
                    toks.append(data["token"])
            assert toks == expected
            assert done["tokens"] == expected
        gateway_session(t)

    def test_ws_stream_survives_replica_kill(self):
        """The CI smoke in miniature: kill the serving replica after the
        first streamed token; the stream must finish on the survivor with
        the exact fault-free tokens."""
        prompt = prompts(1)[0]
        expected = baseline_tokens(prompt, max_new=6)

        async def t(base, sess, router, inj):
            async with sess.ws_connect(base + "/v1/stream") as ws:
                await ws.send_json({"prompt": prompt, "max_new_tokens": 6})
                toks, done = [], None
                async for msg in ws:
                    data = msg.json()
                    if data.get("done"):
                        done = data
                        break
                    toks.append(data["token"])
                    if len(toks) == 1:   # first token: kill its replica
                        [tk] = router.tickets.values()
                        inj.kill(tk.replica_id, at_tick=router.tick)
            assert toks == expected, "stream must not skip or duplicate"
            assert done["reroutes"] == 1
            assert router.counters["replica_restarts"] == 1
        gateway_session(t)
