"""muPallas front-end: lexer/parser/validator/compiler unit tests."""

import numpy as np
import pytest

jnp = pytest.importorskip("jax.numpy")

from repro.core.dsl import (DSLSyntaxError, DSLValidationError, compile_dsl,
                            grammar_stats, lower_dsl, namespace_of, parse,
                            validate_dsl)
from repro.core.dsl.ir import KernelIR, PipelineIR

GEMM = ("gemm().with_dtype(input=fp32, acc=fp32, output=fp32)"
        ".with_tile(m=128, n=128, k=256).with_stages(2)")


class TestParser:
    def test_basic_kernel(self):
        ast = parse(GEMM + " >> bias() >> gelu()")
        assert ast.op.name == "gemm"
        assert [c.name for c in ast.configs] == ["with_dtype", "with_tile",
                                                 "with_stages"]
        assert [e.name for e in ast.epilogues] == ["bias", "gelu"]

    def test_kwargs_and_values(self):
        ast = parse("attention(causal=true, window=4096)"
                    ".with_dtype(input=bf16, acc=fp32, output=bf16)")
        assert ast.op.kwargs == {"causal": True, "window": 4096}

    def test_custom_string_and_dict(self):
        ast = parse(GEMM + " >> custom('x * sigmoid(g)',"
                    " inputs={'g': 'full'})")
        ep = ast.epilogues[0]
        assert ep.args[0] == "x * sigmoid(g)"
        assert ep.kwargs["inputs"] == {"g": "full"}

    def test_pipeline(self):
        ast = parse("pipeline(transpose(input, NCL, NLC, fp32, bf16), "
                    + GEMM + ")")
        assert len(ast.stages) == 2

    def test_syntax_error_has_location(self):
        with pytest.raises(DSLSyntaxError) as e:
            parse("gemm(.with_dtype(input=fp32)")
        assert "E_SYNTAX" in str(e.value)

    def test_trailing_garbage_rejected(self):
        with pytest.raises(DSLSyntaxError):
            parse(GEMM + " gemm()")


class TestValidator:
    def _codes(self, src):
        return {d.code for d in validate_dsl(src)}

    def test_valid_program_no_diagnostics(self):
        assert validate_dsl(GEMM) == []

    def test_missing_dtype_required(self):
        assert "E_DTYPE_REQUIRED" in self._codes("gemm()")

    def test_tile_lane_alignment(self):
        src = ("gemm().with_dtype(input=fp32, acc=fp32, output=fp32)"
               ".with_tile(m=128, n=100, k=256)")
        assert "E_TILE_LANE" in self._codes(src)

    def test_tile_sublane_for_bf16(self):
        src = ("gemm().with_dtype(input=bf16, acc=fp32, output=bf16)"
               ".with_tile(m=8, n=128, k=128)")
        assert "E_TILE_SUBLANE" in self._codes(src)

    def test_vmem_overflow_explained(self):
        src = ("gemm().with_dtype(input=fp32, acc=fp32, output=fp32)"
               ".with_tile(m=4096, n=4096, k=4096).with_stages(4)")
        diags = validate_dsl(src)
        codes = {d.code for d in diags}
        assert "E_TILE_VMEM" in codes
        msg = next(d for d in diags if d.code == "E_TILE_VMEM").message
        assert "MiB" in msg  # explanatory: shows the actual math

    def test_acc_dtype_rule(self):
        src = ("gemm().with_dtype(input=bf16, acc=bf16, output=bf16)"
               ".with_tile(m=128, n=128, k=128)")
        assert "E_ACC_DTYPE" in self._codes(src)

    def test_int8_needs_int32_acc(self):
        src = "gemm().with_dtype(input=int8, acc=fp32, output=int8)"
        assert "E_ACC_DTYPE" in self._codes(src)

    def test_fp8_arch_gating(self):
        src = ("gemm().with_dtype(input=fp8_e4m3, acc=fp32, output=bf16)"
               ".with_arch(tpu_v5e)")
        assert "E_DTYPE_ARCH" in self._codes(src)
        src_ok = ("gemm().with_dtype(input=fp8_e4m3, acc=fp32, output=bf16)"
                  ".with_arch(tpu_v5p)")
        assert "E_DTYPE_ARCH" not in self._codes(src_ok)

    def test_block_on_non_attention_rejected(self):
        src = GEMM + ".with_block(q=128, kv=128)"
        assert "E_CFG_FAMILY" in self._codes(src)

    def test_epilogue_family_gating(self):
        src = ("softmax(axis=-1).with_dtype(input=fp32, acc=fp32,"
               " output=fp32) >> bias()")
        assert "E_EPILOGUE_FAMILY" in self._codes(src)

    def test_custom_expr_whitelist(self):
        src = GEMM + " >> custom('__import__(\"os\")')"
        assert "E_CUSTOM_EXPR" in self._codes(src)

    def test_custom_unknown_name(self):
        src = GEMM + " >> custom('x * y')"
        assert "E_CUSTOM_EXPR" in self._codes(src)

    def test_unknown_op_lists_alternatives(self):
        diags = validate_dsl("jemm().with_dtype(input=fp32, acc=fp32,"
                             " output=fp32)")
        assert diags[0].code == "E_OP_UNKNOWN"
        assert "gemm" in diags[0].hint

    def test_stage_range(self):
        assert "E_STAGES" in self._codes(GEMM.replace(
            ".with_stages(2)", ".with_stages(99)"))

    def test_warnings_do_not_fail(self):
        src = ("gemm().with_dtype(input=bf16, acc=fp32, output=bf16)"
               ".with_tile(m=144, n=128, k=128).with_swap(true)")
        ir, warnings = lower_dsl(src)
        assert {w.code for w in warnings} >= {"W_TILE_MXU", "W_SWAP_DTYPE"}


class TestCompiler:
    def test_backends_agree(self):
        rng = np.random.default_rng(0)
        a = rng.standard_normal((100, 96)).astype(np.float32)
        b = rng.standard_normal((96, 64)).astype(np.float32)
        kp = compile_dsl(GEMM + " >> gelu()", "pallas")
        kx = compile_dsl(GEMM + " >> gelu()", "xla")
        np.testing.assert_allclose(np.asarray(kp(a, b)),
                                   np.asarray(kx(a, b)),
                                   rtol=2e-4, atol=2e-4)

    def test_namespace_deterministic_and_config_sensitive(self):
        ir1, _ = lower_dsl(GEMM)
        ir2, _ = lower_dsl(GEMM)
        ir3, _ = lower_dsl(GEMM.replace("m=128", "m=256"))
        assert namespace_of(ir1) == namespace_of(ir2)
        assert namespace_of(ir1) != namespace_of(ir3)

    def test_source_embeds_dsl(self):
        k = compile_dsl(GEMM, "xla", use_cache=False)
        assert "gemm()" in k.source            # traceability comment
        assert k.namespace.startswith("upallas_")

    def test_cache_hit(self):
        k1 = compile_dsl(GEMM, "pallas")
        k2 = compile_dsl(GEMM, "pallas")
        assert k1 is k2

    def test_swap_requires_square(self):
        src = GEMM + ".with_swap(true)"
        k = compile_dsl(src, "pallas", use_cache=False)
        rng = np.random.default_rng(0)
        with pytest.raises(ValueError, match="square"):
            k(rng.standard_normal((64, 32)).astype(np.float32),
              rng.standard_normal((32, 48)).astype(np.float32))

    def test_pipeline_transform_fused_dtype(self):
        src = ("pipeline(transpose(input, NCL, NLC, fp32, bf16), "
               "conv1d(kernel_w=3).with_dtype(input=bf16, acc=fp32,"
               " output=bf16).with_tile(m=128, n=128, k=128), "
               "transpose(output, NLC, NCL, bf16, fp32))")
        k = compile_dsl(src, "pallas", use_cache=False)
        rng = np.random.default_rng(0)
        x = rng.standard_normal((2, 8, 32)).astype(np.float32)   # NCL
        w = rng.standard_normal((3, 8, 16)).astype(np.float32)
        out = np.asarray(k(x, w))
        assert out.shape == (2, 16, 32)
        assert out.dtype == np.float32

    def test_grammar_fits_in_context(self):
        stats = grammar_stats()
        assert stats["ebnf_lines"] <= 200      # compact like the paper's 170
        assert stats["approx_prompt_tokens"] < 4000
