"""Test-suite isolation: never read/write the developer's persistent
caches, so results match a cold-cache CI run regardless of what
``benchmarks/autotune_sweep.py`` tuned on this machine."""

import os
import tempfile

os.environ.setdefault(
    "REPRO_TUNE_DIR", tempfile.mkdtemp(prefix="repro-tune-tests-"))
os.environ.setdefault(
    "REPRO_COMPILE_CACHE_DIR", "")      # empty -> disk layer off by default
