"""Speculative multi-token decoding: drafters, SOL costing, the tune
axis, engine correctness (bitwise-equal outputs + exact rollback), the
integrity gate's greedy-oracle defence, and the telemetry/capacity
plumbing that prices variable tokens-per-step.

The correctness contract under test: the engine accepts the longest
drafted prefix matching greedy argmax token-for-token and rolls back all
rejected state, so outputs are bitwise-equal to plain greedy decode.  At
draft depth ``k <= 4`` that equality holds exactly on every family here;
wider verify rows can flip near-tie argmaxes via float reassociation
(see the README caveat), which is why the suite pins ``k = 4``.
"""

import copy
import dataclasses

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.configs import get_arch  # noqa: E402
from repro.models.model import build_model  # noqa: E402
from repro.serve import Request, ServeEngine  # noqa: E402
from repro.serve.spec import (AdversarialDrafter, NGramDrafter,  # noqa: E402
                              build_drafter, parse_spec)

ARCH_BY_FAMILY = {
    "dense": "qwen2-0.5b",
    "ssm": "mamba2-1.3b",
    "hybrid": "zamba2-2.7b",
}

_MODELS = {}


def family_model(family):
    if family not in _MODELS:
        cfg = get_arch(ARCH_BY_FAMILY[family]).reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        _MODELS[family] = (model, params)
    return _MODELS[family]


def motif_requests(vocab, n=2, max_new=24, seed0=517):
    """Periodic prompts (4-token motif x 8): the drafter locks on from
    the first decode step, so both accept and commit paths run hot."""
    reqs = []
    for j in range(n):
        rng = np.random.default_rng(seed0 + j)
        motif = list(map(int, rng.integers(1, vocab, 4)))
        reqs.append(Request(rid=j, prompt=motif * 8, max_new_tokens=max_new))
    return reqs


def random_requests(vocab, n=2, max_new=16, seed=3):
    """Free-form prompts: low acceptance, so rejection/rollback runs."""
    rng = np.random.default_rng(seed)
    return [Request(rid=100 + j,
                    prompt=list(map(int, rng.integers(1, vocab, 8))),
                    max_new_tokens=max_new)
            for j in range(n)]


class TestParseSpec:
    def test_accepted_forms(self):
        assert parse_spec(None) is None
        assert parse_spec("off") is None
        assert parse_spec("") is None
        assert parse_spec(0) is None
        assert parse_spec(4) == ("ngram", 4)
        assert parse_spec("4") == ("ngram", 4)
        assert parse_spec("ngram:2") == ("ngram", 2)
        assert parse_spec("draft_model:3") == ("draft_model", 3)

    def test_bad_values_fail_loudly(self):
        with pytest.raises(ValueError):
            parse_spec("telepathy:4")
        with pytest.raises(ValueError):
            parse_spec("ngram:lots")


class TestSOLCosting:
    def test_expected_tokens_envelope(self):
        from repro.core.sol.roofline import spec_expected_tokens
        assert spec_expected_tokens(4, 0.0) == 1.0
        assert spec_expected_tokens(4, 1.0) == 5.0
        assert spec_expected_tokens(0, 0.9) == 1.0
        # E(k, p) = sum_{i=0..k} p^i, strictly increasing in both args
        assert spec_expected_tokens(4, 0.5) == pytest.approx(
            sum(0.5 ** i for i in range(5)))
        assert spec_expected_tokens(4, 0.6) > spec_expected_tokens(4, 0.5)
        assert spec_expected_tokens(6, 0.5) > spec_expected_tokens(4, 0.5)

    def test_roofline_speedup_memory_bound(self):
        from repro.core.sol.roofline import spec_decode_roofline
        # decode shape: weights dominate, verify ~ greedy, so speedup
        # tracks E(k, p) at high acceptance and collapses at p ~ 0
        est = spec_decode_roofline(4, 0.95, flops_per_token=2e6,
                                   weight_bytes=1e6)
        assert est.speedup > 2.0
        assert est.verify.t_sol < 2 * est.greedy.t_sol
        dud = spec_decode_roofline(4, 0.01, flops_per_token=2e6,
                                   weight_bytes=1e6)
        assert dud.speedup < 1.2

    def test_candidates_default_first(self):
        from repro.core import tune
        cands = tune.spec_candidates("decode_block")
        assert cands[0].as_dict() == {"spec": "off"}
        rest = [c.as_dict() for c in cands[1:]]
        # draft_model is opt-in (needs a second param set), not enumerated
        assert {d["spec"] for d in rest} == {"ngram"}
        assert all(d["k"] > 0 for d in rest)

    def test_prune_spec_keeps_off_drops_low_acceptance(self):
        from repro.core import tune
        cands = tune.spec_candidates("decode_block")
        kept = tune.prune_spec(cands, accept_rate=0.9,
                               flops_per_token=2e6, weight_bytes=1e6)
        assert kept[0][0].as_dict() == {"spec": "off"}
        assert len(kept) > 1                 # high acceptance: spec pays
        dead = tune.prune_spec(cands, accept_rate=0.0,
                               flops_per_token=2e6, weight_bytes=1e6)
        assert [c.as_dict() for c, _ in dead] == [{"spec": "off"}]


class TestNGramDrafter:
    def test_longest_suffix_continuation(self):
        d = NGramDrafter()
        #          0  1  2  3  4  5  6  7
        ctx = [5, 8, 9, 2, 5, 8, 9, 4]
        # trailing 1-gram "4" never reoccurred earlier -> fall through to
        # nothing at n=3..1?  no: n is the MATCH length against the tail;
        # tail (9, 4) has no earlier occurrence, tail (4,) neither -> []
        assert d.propose(ctx, 3) == []
        ctx = [5, 8, 9, 2, 5, 8, 9]
        # tail (5, 8, 9) reoccurred at 0; continuation was 2, then 5, 8
        assert d.propose(ctx, 3) == [2, 5, 8]

    def test_periodic_extension_past_context_end(self):
        d = NGramDrafter()
        ctx = [7, 3, 7, 3, 7, 3]
        # period 2: the proposal extends the cycle beyond the context
        assert d.propose(ctx, 5) == [7, 3, 7, 3, 7]

    def test_min_ngram_gates_short_matches(self):
        ctx = [5, 8, 9, 2, 9]           # only a 1-gram match (the 9)
        assert NGramDrafter().propose(ctx, 2) == [2, 9]
        assert NGramDrafter(min_ngram=2).propose(ctx, 2) == []

    def test_stats_count_calls_and_proposals(self):
        d = NGramDrafter()
        d.propose([1, 2, 1], 4)
        d.propose([3], 4)               # too short: no proposal
        s = d.stats()
        assert s["calls"] == 2 and s["proposed"] == 4

    def test_build_drafter_names(self):
        assert build_drafter("ngram").name == "ngram"
        assert build_drafter("adversarial", vocab=16).self_verifying
        with pytest.raises(ValueError):
            build_drafter("nope")


class _OracleDrafter(NGramDrafter):
    """Proposes the TRUE greedy continuation (precomputed per prompt),
    optionally corrupting every ``wrong_every``-th call — a deterministic
    way to drive the accept/commit path on families whose free-running
    output is aperiodic (the n-gram drafter cannot predict a chaotic
    random-init SSM)."""

    def __init__(self, continuations, wrong_every=0):
        super().__init__()
        # {prompt tuple: full greedy out_tokens}
        self.continuations = {tuple(k): list(v)
                              for k, v in continuations.items()}
        self.wrong_every = wrong_every

    def propose(self, context, k):
        self.calls += 1
        ctx = [int(t) for t in context]
        for prompt, out in self.continuations.items():
            n = len(prompt)
            if tuple(ctx[:n]) == prompt and ctx[n:] == out[:len(ctx) - n]:
                done = len(ctx) - n
                drafts = out[done:done + k]
                if self.wrong_every and self.calls % self.wrong_every == 0:
                    drafts = [(t + 1) % 499 for t in drafts]
                self.proposed += len(drafts)
                return drafts
        return []


class TestSpecBitwiseEquality:
    def test_dense_matches_greedy_on_repetitive_workload(self):
        model, params = family_model("dense")
        vocab = model.cfg.vocab_size
        a = motif_requests(vocab)
        b = copy.deepcopy(a)
        eng_s = ServeEngine(model, params, max_batch=2, max_len=72,
                            spec_decode="ngram:4")
        eng_s.run(a)
        eng_g = ServeEngine(model, params, max_batch=2, max_len=72,
                            spec_decode="off")
        eng_g.run(b)
        assert [r.out_tokens for r in a] == [r.out_tokens for r in b]
        assert eng_s.metrics["spec_accepted_tokens"] > 0
        assert eng_s.metrics["steps"] < eng_g.metrics["steps"]
        assert eng_s.spec_mode == "prefix"

    @pytest.mark.parametrize("family", ["ssm", "hybrid"])
    @pytest.mark.parametrize("wrong_every", [0, 3])
    def test_replay_families_accept_with_oracle_drafter(self, family,
                                                        wrong_every):
        """Replay-mode commit (and, with ``wrong_every``, the mixed
        accept-then-reject path) must preserve bitwise equality while
        accepting tokens and saving steps."""
        model, params = family_model(family)
        vocab = model.cfg.vocab_size
        b = motif_requests(vocab)
        eng_g = ServeEngine(model, params, max_batch=2, max_len=72,
                            spec_decode="off")
        eng_g.run(b)
        oracle = _OracleDrafter({tuple(r.prompt): r.out_tokens for r in b},
                                wrong_every=wrong_every)
        a = motif_requests(vocab)
        eng_s = ServeEngine(model, params, max_batch=2, max_len=72,
                            spec_decode="ngram:4", drafter=oracle)
        assert eng_s.spec_mode == "replay"
        eng_s.run(a)
        assert [r.out_tokens for r in a] == [r.out_tokens for r in b]
        assert eng_s.metrics["spec_accepted_tokens"] > 0
        assert eng_s.metrics["steps"] < eng_g.metrics["steps"]
        if wrong_every:
            assert eng_s.metrics["spec_rollbacks"] > 0

    @pytest.mark.parametrize("family", ["dense", "ssm"])
    def test_matches_greedy_with_rejections(self, family):
        """Free-form prompts: most drafts are wrong, so the rollback path
        (not just the accept path) must preserve greedy equality."""
        model, params = family_model(family)
        vocab = model.cfg.vocab_size
        a = random_requests(vocab, max_new=40)
        b = copy.deepcopy(a)
        eng_s = ServeEngine(model, params, max_batch=2, max_len=64,
                            spec_decode="ngram:4")
        eng_s.run(a)
        ServeEngine(model, params, max_batch=2, max_len=64,
                    spec_decode="off").run(b)
        assert [r.out_tokens for r in a] == [r.out_tokens for r in b]
        assert eng_s.metrics["spec_rollbacks"] > 0


class _WrongDrafter(NGramDrafter):
    """Proposes confidently and is always wrong: every draft is rejected,
    so every drafting step exercises a full rollback."""

    def __init__(self, vocab):
        super().__init__()
        self.vocab = vocab

    def propose(self, context, k):
        last = int(context[-1]) if len(context) else 0
        return [(last + 1 + i) % self.vocab for i in range(k)]


class TestRollbackRestoresState:
    @pytest.mark.parametrize("family", ["dense", "ssm"])
    def test_all_rejected_still_bitwise_and_slots_reusable(self, family):
        model, params = family_model(family)
        vocab = model.cfg.vocab_size
        a = motif_requests(vocab, max_new=12)
        b = copy.deepcopy(a)
        eng_s = ServeEngine(model, params, max_batch=2, max_len=60,
                            spec_decode="ngram:4",
                            drafter=_WrongDrafter(vocab))
        eng_s.run(a)
        eng_g = ServeEngine(model, params, max_batch=2, max_len=60,
                            spec_decode="off")
        eng_g.run(b)
        assert [r.out_tokens for r in a] == [r.out_tokens for r in b]
        assert eng_s.metrics["spec_accepted_tokens"] == 0
        assert eng_s.metrics["spec_rollbacks"] > 0
        # the rolled-back cache must leave NO residue: a second wave on
        # the same engines (reusing the slots) stays bitwise-equal too
        a2 = random_requests(vocab, seed=9)
        b2 = copy.deepcopy(a2)
        eng_s.run(a2)
        eng_g.run(b2)
        assert [r.out_tokens for r in a2] == [r.out_tokens for r in b2]

    def test_prefix_rewind_restores_positions(self):
        model, params = family_model("dense")
        vocab = model.cfg.vocab_size
        eng_s = ServeEngine(model, params, max_batch=2, max_len=60,
                            spec_decode="ngram:4",
                            drafter=_WrongDrafter(vocab))
        eng_s.run(motif_requests(vocab, max_new=12))
        eng_g = ServeEngine(model, params, max_batch=2, max_len=60,
                            spec_decode="off")
        eng_g.run(motif_requests(vocab, max_new=12))

        def pos_leaves(cache):
            out = []
            jax.tree_util.tree_map_with_path(
                lambda p, leaf: out.append(np.asarray(leaf))
                if str(getattr(p[-1], "key", p[-1])) == "pos" else None,
                cache)
            return out

        for ps, pg in zip(pos_leaves(eng_s.cache), pos_leaves(eng_g.cache)):
            np.testing.assert_array_equal(ps, pg)


class TestSpecTuneAxis:
    dims = property(lambda self: (family_model("dense")[0].cfg.d_model,
                                  family_model("dense")[0].cfg.d_ff))
    dtype = property(
        lambda self: family_model("dense")[0].cfg.compute_dtype)

    def _engine(self, spec_decode=None, **kw):
        model, params = family_model("dense")
        return ServeEngine(model, params, max_batch=2, max_len=48,
                           spec_decode=spec_decode, **kw)

    def test_off_by_default_when_unmeasured(self, tmp_path, monkeypatch):
        monkeypatch.setenv("REPRO_TUNE_DIR", str(tmp_path))
        monkeypatch.delenv("REPRO_SPEC", raising=False)
        from repro.core import tune
        assert tune.tuned_spec("decode_block", self.dims, self.dtype) is None
        assert self._engine().spec is None

    def test_measured_record_turns_spec_on(self, tmp_path, monkeypatch):
        """The lever is lossless, so unlike quant/shard a measured record
        may flip the default ON, not only veto it."""
        monkeypatch.setenv("REPRO_TUNE_DIR", str(tmp_path))
        monkeypatch.delenv("REPRO_SPEC", raising=False)
        from repro.core import tune
        tune.record_spec_measurement("decode_block", self.dims,
                                     self.dtype,
                                     spec_best="ngram", k=4,
                                     accept_rate=0.9)
        eng = self._engine()
        assert eng.spec == ("ngram", 4)
        # the tuned acceptance rate prices expected tokens per step
        assert eng.expected_tokens_per_step == pytest.approx(
            sum(0.9 ** i for i in range(5)))

    def test_veto_flips_off_but_explicit_forces(self, tmp_path,
                                                monkeypatch):
        monkeypatch.setenv("REPRO_TUNE_DIR", str(tmp_path))
        monkeypatch.delenv("REPRO_SPEC", raising=False)
        from repro.core import tune
        tune.record_spec_measurement("decode_block", self.dims,
                                     self.dtype,
                                     spec_best="off", accept_rate=0.05)
        assert self._engine().spec is None
        forced = self._engine(spec_decode="ngram:4")
        assert forced.spec == ("ngram", 4)
        assert forced.model.cfg.spec_decode == "ngram:4"

    def test_escape_hatch_beats_everything(self, tmp_path, monkeypatch):
        monkeypatch.setenv("REPRO_TUNE_DIR", str(tmp_path))
        monkeypatch.setenv("REPRO_SPEC", "off")
        from repro.core import tune
        tune.record_spec_measurement("decode_block", self.dims,
                                     self.dtype,
                                     spec_best="ngram", k=4,
                                     accept_rate=0.9)
        assert tune.tuned_spec("decode_block", self.dims, self.dtype) is None
        assert self._engine().spec is None
        assert self._engine(spec_decode="ngram:4").spec is None

    def test_sliding_window_structural_gate(self, tmp_path, monkeypatch):
        """A windowed KV ring evicts entries on write, so drafted tokens
        cannot be rolled back — the structural gate beats an explicit
        request."""
        monkeypatch.setenv("REPRO_TUNE_DIR", str(tmp_path))
        monkeypatch.delenv("REPRO_SPEC", raising=False)
        cfg = dataclasses.replace(get_arch("qwen2-0.5b").reduced(),
                                  sliding_window=8)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        eng = ServeEngine(model, params, max_batch=2, max_len=48,
                          spec_decode="ngram:4")
        assert eng.spec is None


class TestAdversarialDrafterQuarantine:
    def test_self_verifying_drafter_diverges_and_is_quarantined(
            self, tmp_path, monkeypatch):
        """The planted gaming mode end-to-end: a drafter that claims its
        tokens need no verification books a perfect acceptance rate, but
        the greedy-oracle check quarantines the recorded config and the
        tuner stops serving it."""
        monkeypatch.setenv("REPRO_TUNE_DIR", str(tmp_path))
        monkeypatch.delenv("REPRO_SPEC", raising=False)
        monkeypatch.delenv("REPRO_INTEGRITY", raising=False)
        from repro.core import tune
        from repro.core.integrity import (QUARANTINE, gate_spec_claim,
                                          global_ledger, ledger_key)

        model, params = family_model("dense")
        vocab = model.cfg.vocab_size
        a = motif_requests(vocab, max_new=12)
        b = copy.deepcopy(a)
        eng = ServeEngine(model, params, max_batch=2, max_len=60,
                          spec_decode="ngram:4",
                          drafter=AdversarialDrafter(vocab=vocab))
        assert eng.spec_trusted
        eng.run(a)
        ServeEngine(model, params, max_batch=2, max_len=60,
                    spec_decode="off").run(b)
        spec_toks = [t for r in a for t in r.out_tokens]
        greedy_toks = [t for r in b for t in r.out_tokens]
        assert spec_toks != greedy_toks, \
            "the adversarial drafter must actually corrupt outputs"

        # the attack recorded its fake verdict into the tuning cache
        dims = (model.cfg.d_model, model.cfg.d_ff)
        dtype = model.cfg.compute_dtype
        tune.record_spec_measurement("decode_block", dims, dtype,
                                     spec_best="ngram", k=4,
                                     accept_rate=1.0, speedup=5.0)
        best = tune.lookup("spec:decode_block", dims, dtype)
        assert best is not None

        verdict = gate_spec_claim("decode_block", spec_tokens=spec_toks,
                                  greedy_tokens=greedy_toks, config=best,
                                  accept_rate=1.0)
        assert verdict.decision == QUARANTINE
        assert "oracle_mismatch" in verdict.reason_codes
        assert "diverges_at" in verdict.checks[0].evidence

        global_ledger().quarantine(
            ledger_key("spec:decode_block", dims, dtype), best, verdict)
        assert tune.tuned_spec("decode_block", dims, dtype) is None

    def test_gate_accepts_honest_claim(self):
        from repro.core.integrity import ACCEPT, gate_spec_claim
        toks = [1, 2, 3, 4]
        v = gate_spec_claim("decode_block", spec_tokens=toks,
                            greedy_tokens=list(toks), accept_rate=0.8)
        assert v.decision == ACCEPT


class TestSpecTelemetry:
    def test_tokens_per_step_and_accept_ratio(self):
        model, params = family_model("dense")
        vocab = model.cfg.vocab_size
        eng = ServeEngine(model, params, max_batch=2, max_len=72,
                          spec_decode="ngram:4")
        reqs = motif_requests(vocab)
        eng.run(reqs)
        summ = eng.telemetry.summary()
        assert summ["tokens_per_step"] > 1.0
        assert 0.0 < summ["spec_accept_ratio"] <= 1.0
        assert summ["spec_accepted"] == eng.metrics["spec_accepted_tokens"]

    def test_per_token_timestamps_cover_burst_emissions(self):
        """A multi-token verify step must stamp EVERY emitted token, so
        ITL gaps include the ~0s intra-burst gaps (per-step timing would
        overstate the tail)."""
        model, params = family_model("dense")
        vocab = model.cfg.vocab_size
        eng = ServeEngine(model, params, max_batch=2, max_len=72,
                          spec_decode="ngram:4")
        reqs = motif_requests(vocab)
        eng.run(reqs)
        for r in reqs:
            trace = eng.telemetry.traces[r.rid]
            assert len(trace.token_times) == len(r.out_tokens)
            assert len(trace.itl_gaps) == len(r.out_tokens) - 1
            assert all(g >= 0 for g in trace.itl_gaps)

    def test_gateway_spec_gauges(self):
        from repro.core.obs.metrics import MetricsRegistry
        from repro.serve import build_replicated_router
        from repro.serve.gateway import update_fleet_gauges
        model, params = family_model("dense")
        vocab = model.cfg.vocab_size
        router = build_replicated_router(model, params, replicas=1,
                                         max_batch=2, max_len=72,
                                         spec_decode="ngram:4")
        reqs = motif_requests(vocab)
        tickets = [router.submit(r.prompt,
                                 max_new_tokens=r.max_new_tokens)
                   for r in reqs]
        router.run_until_complete(tickets, max_ticks=10000)
        reg = MetricsRegistry()
        update_fleet_gauges(router, reg)
        text = reg.render_prometheus()
        assert "repro_tokens_per_step" in text
        assert "repro_spec_accept_ratio" in text
        tps = [ln for ln in text.splitlines()
               if ln.startswith("repro_tokens_per_step")][0]
        assert float(tps.split()[-1]) > 1.0


class TestCapacityPricing:
    def test_sol_scheduler_itl_budget_scales(self):
        from repro.serve import EngineView, SOLCapacityModel, SOLScheduler
        cfg = get_arch("qwen2-0.5b").reduced()
        view = EngineView(step=0, free_slots=1, decode_positions=[16],
                          decode_slos=["interactive"], prefill_backlog=0)
        base = SOLScheduler(SOLCapacityModel(cfg))
        spec = SOLScheduler(SOLCapacityModel(
            cfg, expected_tokens_per_step=4.0))
        assert spec._itl_budget(view) == pytest.approx(
            4.0 * base._itl_budget(view))

    def test_fleet_drain_scales_with_expected_tokens(self):
        from repro.core.sol.fleet import FleetCapacityModel, ReplicaLoad
        from repro.serve import SOLCapacityModel
        cfg = get_arch("qwen2-0.5b").reduced()
        cap = SOLCapacityModel(cfg)
        load = ReplicaLoad(replica_id=0, free_slots=0, queue_depth=2,
                           decode_positions=[8, 8], prefill_backlog=0)
        greedy = FleetCapacityModel(cap)
        spec = FleetCapacityModel(cap, expected_tokens_per_step=4.0)
        assert spec.drain_estimate_s(load) == pytest.approx(
            greedy.drain_estimate_s(load) / 4.0)
