"""Block-paged KV/SSM cache: pool mechanics (reservations, refcounts,
COW), prefix sharing by page-table splice, refcount-idle eviction before
rejection, the bytes-priced ``pool_exhausted`` admission verdict, spec
rollback page unmapping, and the host-only slot free (a poisoned pool
must never leak into outputs)."""

import copy
import dataclasses

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_arch  # noqa: E402
from repro.core.sol.fleet import (FleetCapacityModel,  # noqa: E402
                                  ReplicaLoad)
from repro.models.model import build_model  # noqa: E402
from repro.serve import (PagePool, PrefixCache, Request,  # noqa: E402
                         RouterRejected, ServeEngine, SOLCapacityModel,
                         build_replicated_router, fleet_summary)
from repro.serve.spec import NGramDrafter  # noqa: E402

ARCH_BY_FAMILY = {
    "dense": "qwen2-0.5b",
    "ssm": "mamba2-1.3b",
    "hybrid": "zamba2-2.7b",
}

_MODELS = {}


def family_model(family):
    if family not in _MODELS:
        cfg = get_arch(ARCH_BY_FAMILY[family]).reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        _MODELS[family] = (model, params)
    return _MODELS[family]


def make_requests(vocab, n=4, prompt_len=6, max_new=5, seed=0, rid0=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=rid0 + i,
                    prompt=list(map(int, rng.integers(1, vocab,
                                                      prompt_len))),
                    max_new_tokens=max_new)
            for i in range(n)]


class _WrongDrafter(NGramDrafter):
    """Always-wrong proposals: every drafting step is a full rollback."""

    def __init__(self, vocab):
        super().__init__()
        self.vocab = vocab

    def propose(self, context, k):
        last = int(context[-1]) if len(context) else 0
        return [(last + 1 + i) % self.vocab for i in range(k)]


# ---------------------------------------------------------------------------
# pool mechanics (host-side, no model)
# ---------------------------------------------------------------------------

class TestPagePool:
    def _pool(self, **kw):
        kw.setdefault("n_pages", 8)
        kw.setdefault("page_size", 4)
        kw.setdefault("n_slots", 2)
        kw.setdefault("max_pages", 4)
        kw.setdefault("page_nbytes", 100)
        return PagePool(**kw)

    def test_reservation_guards_admission(self):
        pool = self._pool()
        assert pool.can_admit(8)
        pool.reserve_slot(0, 3)
        # 3 of the 8 free pages are promised: only 5 remain admittable
        assert pool.available() == 5
        assert not pool.can_admit(6)
        # mapping draws DOWN the reservation, not double-counting
        pool.ensure_mapped(0, 9)         # 3 pages of 4 tokens
        assert pool.mapped_count(0) == 3
        assert pool.available() == 5
        pool.clear_slot(0)
        assert pool.available() == 8

    def test_mid_step_exhaustion_is_a_loud_error(self):
        pool = self._pool(n_pages=2)
        pool.ensure_mapped(0, 8)
        with pytest.raises(RuntimeError, match="exhausted"):
            pool.ensure_mapped(1, 4)

    def test_unmap_from_keeps_partial_pages(self):
        pool = self._pool()
        pool.reserve_slot(0, 4)
        pool.ensure_mapped(0, 16)
        # position 6 is inside page 1: pages 2..3 free, 0..1 stay
        freed = pool.unmap_from(0, 6)
        assert len(freed) == 2 and pool.mapped_count(0) == 2
        # the freed pages re-credit the reservation for later growth
        assert pool.available() == 8 - 4
        pool.ensure_mapped(0, 16)
        assert pool.mapped_count(0) == 4

    def test_share_splice_refcounts_and_cow(self):
        pool = self._pool()
        pool.ensure_mapped(0, 6)                  # 2 pages, partial 2nd
        entry_pages = pool.share_prefix(0, 6)
        assert [int(pool.refcount[p]) for p in entry_pages] == [2, 2]
        pool.clear_slot(0)                        # entry keeps them alive
        assert [int(pool.refcount[p]) for p in entry_pages] == [1, 1]
        assert pool.pages_free == 6

        # a hit splices the entry's pages into slot 1 (refcount 2 again);
        # the partial last page keeps one reserved page as COW margin
        pool.reserve_slot(1, 3)
        pool.splice(1, entry_pages, 6)
        assert pool.pages_shared == 2
        assert int(pool._reserved[1]) == 2        # 1 full page released
        # writing into the partial shared page triggers exactly one COW
        targets = pool.cow_targets(1, 6, 8)
        assert [j for j, _ in targets] == [1]
        dst, src = pool.remap_cow(1, 1)
        assert dst != src and int(pool.refcount[src]) == 1
        assert int(pool.table[1, 1]) == dst
        # the entry's copy is untouched; no further COW needed
        assert pool.cow_targets(1, 6, 8) == []

    def test_clear_slot_is_host_only_bookkeeping(self):
        pool = self._pool(n_state_pages=2, state_page_nbytes=10)
        pool.ensure_mapped(0, 16)
        pool.alloc_state(0)
        assert pool.used_bytes == 4 * 100 + 10
        pool.clear_slot(0)
        assert pool.pages_free == 8 and pool.state_pages_free == 2
        assert pool.used_bytes == 0
        assert pool.peak_used_bytes == 410


# ---------------------------------------------------------------------------
# prefix sharing: splice + COW under a live engine
# ---------------------------------------------------------------------------

class TestPagedPrefixSharing:
    def test_splice_cow_and_entry_refcounts(self):
        """Three requests share a 12-token prefix; page_size 8 makes the
        entry's 2nd page PARTIAL, so every adopter COWs it on its first
        append.  The entry's copy must survive every adoption (later hits
        still bit-identical), with zero host copies throughout."""
        model, params = family_model("dense")
        vocab = model.cfg.vocab_size
        rng = np.random.default_rng(42)
        system = list(map(int, rng.integers(1, vocab, 12)))
        reqs = [Request(rid=i,
                        prompt=system + list(map(int,
                                                 rng.integers(1, vocab, 3))),
                        max_new_tokens=4)
                for i in range(3)]
        with_cache = copy.deepcopy(reqs)
        without = copy.deepcopy(reqs)
        eng = ServeEngine(model, params, max_batch=2, max_len=32,
                          chunk_size=4, prefix_cache=True, page_size=8)
        assert eng.paged
        eng.run(with_cache)
        ServeEngine(model, params, max_batch=2, max_len=32,
                    chunk_size=4).run(without)
        assert [r.out_tokens for r in with_cache] == \
            [r.out_tokens for r in without]
        pc = eng.prefix_cache
        assert eng.metrics["prefix_hits"] > 0
        assert pc.stats()["host_copies"] == 0
        # slots are all free, so refcounts are exactly the entry
        # references (nested prefix entries may share underlying pages)
        pool = eng.pool
        holders = {}
        for entry in pc._store.values():
            assert entry.paged
            for page in entry.page_ids:
                holders[page] = holders.get(page, 0) + 1
        assert holders, "paged entries should have been put"
        for page in range(pool.n_pages):
            assert int(pool.refcount[page]) == holders.get(page, 0)
        assert pc.reclaimable_pages(pool) > 0

    def test_shared_refcount_while_adopter_is_live(self):
        """Mid-flight, a spliced page is held by the entry AND the slot:
        refcount 2 -> pages_shared > 0 in the engine's step metrics."""
        model, params = family_model("dense")
        vocab = model.cfg.vocab_size
        rng = np.random.default_rng(7)
        system = list(map(int, rng.integers(1, vocab, 16)))
        reqs = [Request(rid=i,
                        prompt=system + list(map(int,
                                                 rng.integers(1, vocab, 2))),
                        max_new_tokens=8)
                for i in range(2)]
        eng = ServeEngine(model, params, max_batch=2, max_len=48,
                          chunk_size=8, prefix_cache=True, page_size=8)
        shared_seen = 0
        for _ in eng.stream(reqs):
            shared_seen = max(shared_seen, eng.metrics["pages_shared"])
        assert shared_seen > 0


# ---------------------------------------------------------------------------
# admission: eviction before rejection, priced verdicts
# ---------------------------------------------------------------------------

class TestPoolAdmission:
    def test_refcount_idle_prefix_pages_evict_before_rejection(self):
        """A request whose page demand exceeds the free pool must reclaim
        refcount-idle prefix pages (evicting entries) instead of being
        deferred forever."""
        model, params = family_model("dense")
        vocab = model.cfg.vocab_size
        rng = np.random.default_rng(0)
        system = list(map(int, rng.integers(1, vocab, 16)))
        warm = [Request(rid=i,
                        prompt=system + list(map(int,
                                                 rng.integers(1, vocab, 2))),
                        max_new_tokens=2)
                for i in range(2)]
        eng = ServeEngine(model, params, max_batch=1, max_len=32,
                          chunk_size=8, prefix_cache=True, page_size=8,
                          pool_pages=6)
        eng.run(warm)
        pc = eng.prefix_cache
        assert len(pc) > 0 and pc.reclaimable_pages(eng.pool) > 0
        free_before = eng.pool.pages_free
        # worst-case demand: 4 pages + 1 COW margin > the free pool
        big = Request(rid=9, prompt=list(map(int,
                                             rng.integers(1, vocab, 26))),
                      max_new_tokens=6)
        assert free_before < 5
        eng.run([big])
        assert big.done
        assert pc.evictions > 0, \
            "admission must evict idle prefix pages before deferring"

    def test_fleet_verdict_prices_pool_exhaustion_in_bytes(self):
        cfg = get_arch("qwen2-0.5b").reduced()
        cap = SOLCapacityModel(cfg, efficiency=0.5)
        fleet = FleetCapacityModel(cap)
        load = ReplicaLoad(replica_id=0, free_slots=2, num_slots=4,
                           queue_depth=0, decode_positions=(8, 8),
                           pages_free=2, pages_reclaimable=0,
                           pages_total=16, page_size=8,
                           state_pages_free=0)
        verdict = fleet.verdict([load], prompt_tokens=20,
                                max_new_tokens=20)
        assert not verdict.admit
        assert verdict.reason == "pool_exhausted"
        assert verdict.retry_after_s > 0
        # the deficit is priced in exact page bytes
        deficit = fleet.pool_deficit_bytes(load, 20, 20)
        assert deficit == 3 * cap.kv_page_bytes(8)
        # reclaimable prefix pages count as capacity: same demand admits
        load2 = dataclasses.replace(load, pages_reclaimable=3)
        assert fleet.verdict([load2], prompt_tokens=20,
                             max_new_tokens=20).admit
        # dense replicas (no pool) never hit the pool term
        load3 = dataclasses.replace(load, pages_total=0, page_size=0)
        assert fleet.verdict([load3], prompt_tokens=20,
                             max_new_tokens=20).admit

    def test_router_rejects_with_priced_retry_after(self):
        model, params = family_model("dense")
        router = build_replicated_router(
            model, params, replicas=1, max_batch=4, max_len=64,
            chunk_size=8, prefix_cache=False, page_size=8, pool_pages=4)
        big = list(range(1, 21))
        with pytest.raises(RouterRejected) as exc:
            router.submit(big, max_new_tokens=20)
        assert exc.value.reason == "pool_exhausted"
        assert exc.value.retry_after_s > 0
        # a request that fits the 4-page pool is admitted normally
        ticket = router.submit(big[:8], max_new_tokens=4)
        router.run_until_complete([ticket], max_ticks=10000)
        assert ticket.status == "done"


# ---------------------------------------------------------------------------
# speculative rollback returns pages
# ---------------------------------------------------------------------------

class TestSpecRollbackUnmapsPages:
    @pytest.mark.parametrize("family", ["dense", "ssm", "hybrid"])
    def test_all_rejected_unmaps_and_stays_bitwise(self, family):
        model, params = family_model(family)
        vocab = model.cfg.vocab_size
        a = make_requests(vocab, n=2, prompt_len=8, max_new=10, seed=3)
        b = copy.deepcopy(a)
        eng_s = ServeEngine(model, params, max_batch=2, max_len=48,
                            spec_decode="ngram:4",
                            drafter=_WrongDrafter(vocab), page_size=8)
        assert eng_s.paged
        eng_s.run(a)
        ServeEngine(model, params, max_batch=2, max_len=48,
                    spec_decode="off").run(b)
        assert [r.out_tokens for r in a] == [r.out_tokens for r in b]
        assert eng_s.metrics["spec_rollbacks"] > 0
        # every page and state page came back: nothing leaked across the
        # draft/reject cycles or the final slot release
        pool = eng_s.pool
        assert pool.pages_free == pool.n_pages
        assert pool.state_pages_free == pool.n_state_pages
        assert pool.available() == pool.n_pages


# ---------------------------------------------------------------------------
# host-only free: a poisoned pool must never reach outputs
# ---------------------------------------------------------------------------

class TestPoisonedPool:
    @pytest.mark.parametrize("family", ["dense", "ssm", "hybrid"])
    def test_freed_page_garbage_never_leaks(self, family):
        """Freeing a slot is page-table bookkeeping only — the page
        CONTENT is left stale.  Overwrite every pool page with large
        finite garbage between waves; wave 2 must still be bit-identical
        to a fresh engine (validity masks + alloc-time state zeroing are
        what correctness rests on, never zeroed-on-free memory)."""
        model, params = family_model(family)
        vocab = model.cfg.vocab_size
        eng = ServeEngine(model, params, max_batch=2, max_len=32,
                          chunk_size=4, page_size=8)
        assert eng.paged
        eng.run(make_requests(vocab, seed=1))

        def poison(path, leaf):
            name = str(getattr(path[-1], "key", path[-1]))
            if name == "pos":
                return leaf
            return jnp.full_like(leaf, 1e9)

        eng.cache = jax.tree_util.tree_map_with_path(poison, eng.cache)
        wave = make_requests(vocab, seed=2, rid0=10)
        fresh = copy.deepcopy(wave)
        eng.run(wave)
        ServeEngine(model, params, max_batch=2, max_len=32,
                    chunk_size=4, page_size=8).run(fresh)
        assert [r.out_tokens for r in wave] == \
            [r.out_tokens for r in fresh]


# ---------------------------------------------------------------------------
# telemetry, gates, escape hatch
# ---------------------------------------------------------------------------

class TestPagedPlumbing:
    def test_pool_gauges_flow_to_metrics_and_fleet_summary(self):
        model, params = family_model("dense")
        vocab = model.cfg.vocab_size
        eng = ServeEngine(model, params, max_batch=2, max_len=32,
                          page_size=8)
        eng.run(make_requests(vocab, n=2))
        assert eng.metrics["pages_total"] == eng.pool.n_pages
        summ = eng.telemetry.summary()
        assert summ["pool_pages_total"] == eng.pool.n_pages
        assert summ["pool_pages_free"] == eng.pool.pages_free
        fleet = fleet_summary([eng.telemetry])
        assert fleet["pool_pages_total"] == eng.pool.n_pages
        assert "hbm_pool_used_bytes" in fleet
        assert "prefix_pages_shared" in fleet

    def test_escape_hatch_and_structural_gates(self, monkeypatch):
        model, params = family_model("dense")
        monkeypatch.setenv("REPRO_PAGED", "off")
        eng = ServeEngine(model, params, max_batch=2, max_len=32,
                          page_size=8)
        assert not eng.paged and eng.pool is None
        monkeypatch.delenv("REPRO_PAGED")
        # a wrapping sliding window keeps the dense ring cache
        cfg = dataclasses.replace(get_arch("qwen2-0.5b").reduced(),
                                  sliding_window=8)
        wmodel = build_model(cfg)
        wparams = wmodel.init(jax.random.PRNGKey(0))
        weng = ServeEngine(wmodel, wparams, max_batch=2, max_len=32,
                           page_size=8)
        assert not weng.paged

    def test_cfg_page_size_enables_paging(self):
        cfg = dataclasses.replace(get_arch("qwen2-0.5b").reduced(),
                                  page_size=8)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        eng = ServeEngine(model, params, max_batch=2, max_len=32)
        assert eng.paged and eng.page_size == 8
        # an explicit 0 forces dense past the config
        assert not ServeEngine(model, params, max_batch=2, max_len=32,
                               page_size=0).paged
