"""Serving subsystem: chunked prefill, slot reuse, truncation, prefix
cache, scheduler/capacity model, streaming, telemetry."""

import copy

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_arch  # noqa: E402
from repro.models.model import build_model  # noqa: E402
from repro.serve import (EngineView, FIFOScheduler, PrefixCache,  # noqa: E402
                         Request, ServeEngine, SOLCapacityModel,
                         SOLScheduler, collect_streams, percentile)

ARCH_BY_FAMILY = {
    "dense": "qwen2-0.5b",
    "ssm": "mamba2-1.3b",
    "hybrid": "zamba2-2.7b",
}

_MODELS = {}


def family_model(family):
    if family not in _MODELS:
        cfg = get_arch(ARCH_BY_FAMILY[family]).reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        _MODELS[family] = (model, params)
    return _MODELS[family]


def make_requests(vocab, n=4, prompt_len=6, max_new=5, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=list(map(int, rng.integers(1, vocab, prompt_len))),
                    max_new_tokens=max_new)
            for i in range(n)]


class TestChunkedPrefill:
    def test_chunked_matches_token_mode_dense(self):
        """Attention prefill chunks are bit-exact vs one-token-at-a-time
        (same softmax column order, masked columns contribute exact 0)."""
        model, params = family_model("dense")
        vocab = model.cfg.vocab_size
        a = make_requests(vocab)
        b = copy.deepcopy(a)
        ServeEngine(model, params, max_batch=2, max_len=32,
                    prefill_mode="chunked", chunk_size=4).run(a)
        ServeEngine(model, params, max_batch=2, max_len=32,
                    prefill_mode="token").run(b)
        assert [r.out_tokens for r in a] == [r.out_tokens for r in b]

    def test_chunked_fewer_steps(self):
        model, params = family_model("dense")
        vocab = model.cfg.vocab_size
        e1 = ServeEngine(model, params, max_batch=2, max_len=32,
                         prefill_mode="chunked", chunk_size=8)
        e1.run(make_requests(vocab))
        e2 = ServeEngine(model, params, max_batch=2, max_len=32,
                         prefill_mode="token")
        e2.run(make_requests(vocab))
        assert e1.metrics["steps"] < e2.metrics["steps"]

    def test_windowed_model_chunk_clamped_to_ring(self):
        """Sliding-window model with an oversized chunk: the engine clamps
        the chunk to the KV ring so one chunk can never scatter two tokens
        to the same ring slot, and chunked prefill stays consistent with
        the decode-step reference."""
        import dataclasses
        cfg = dataclasses.replace(get_arch("qwen2-0.5b").reduced(),
                                  sliding_window=8)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        # model level: one 12-token ragged prefill vs decode-step feeding,
        # with prompts longer than the 8-slot ring
        rng = np.random.default_rng(5)
        prompt = rng.integers(1, cfg.vocab_size, 12)
        cache = model.init_cache(1, 32)
        for t in prompt:
            ref, cache = model.decode_step(params, cache,
                                           jnp.array([[t]], jnp.int32))
        last, _ = model.prefill(params, jnp.array([prompt], jnp.int32), 32)
        np.testing.assert_allclose(np.asarray(last[0]),
                                   np.asarray(ref[0, -1]),
                                   rtol=0, atol=5e-2)
        # engine level: an absurd chunk request is clamped to the ring
        engine = ServeEngine(model, params, max_batch=2, max_len=32,
                             prefill_mode="chunked", chunk_size=1000)
        assert engine.planner.chunk_size == 8
        reqs = make_requests(cfg.vocab_size, n=2, prompt_len=12, max_new=4)
        engine.run(reqs)
        assert all(r.done and len(r.out_tokens) == 4 for r in reqs)

    @pytest.mark.parametrize("family", ["dense", "ssm", "hybrid"])
    def test_prefill_matches_decode_reference(self, family):
        """model.prefill == feeding the prompt through decode_step."""
        model, params = family_model(family)
        rng = np.random.default_rng(3)
        prompt = rng.integers(1, model.cfg.vocab_size, 7)
        cache = model.init_cache(1, 32)
        for t in prompt:
            ref, cache = model.decode_step(params, cache,
                                           jnp.array([[t]], jnp.int32))
        last, _ = model.prefill(params, jnp.array([prompt], jnp.int32), 32)
        np.testing.assert_allclose(np.asarray(last[0]),
                                   np.asarray(ref[0, -1]),
                                   rtol=0, atol=5e-2)

    @pytest.mark.parametrize("family", ["dense", "ssm", "hybrid"])
    def test_counts_zero_is_exact_noop(self, family):
        """A zero-count prefill row must leave the slot's state bitwise
        untouched — the invariant that lets decode and prefill share one
        jit step."""
        model, params = family_model(family)
        cache = model.init_cache(2, 32)
        _, cache = model.prefill_step(
            params, cache, jnp.array([[3, 5, 7, 9], [0, 0, 0, 0]],
                                     jnp.int32), jnp.array([4, 0]))
        before = jax.tree.map(np.asarray, cache)
        _, cache = model.prefill_step(
            params, cache, jnp.array([[1, 2, 3, 4], [5, 6, 7, 8]],
                                     jnp.int32), jnp.array([0, 0]))
        after = jax.tree.map(np.asarray, cache)
        for x, y in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
            np.testing.assert_array_equal(x, y)


class TestSlotReuse:
    @pytest.mark.parametrize("family", ["dense", "ssm", "hybrid"])
    def test_released_slot_has_no_stale_state(self, family):
        """A request admitted into a just-released slot must produce the
        same outputs as on a fresh engine (KV/SSM state fully reset)."""
        model, params = family_model(family)
        vocab = model.cfg.vocab_size
        probe = Request(rid=99, prompt=[3, 5, 7, 11], max_new_tokens=4)

        fresh_probe = copy.deepcopy(probe)
        ServeEngine(model, params, max_batch=1, max_len=32,
                    chunk_size=4).run([fresh_probe])

        # 1 slot, 3 requests: the probe lands in a slot two others used
        reused = ServeEngine(model, params, max_batch=1, max_len=32,
                             chunk_size=4)
        fillers = make_requests(vocab, n=2, prompt_len=5, max_new=6, seed=7)
        reused_probe = copy.deepcopy(probe)
        reused.run(fillers + [reused_probe])
        assert reused_probe.out_tokens == fresh_probe.out_tokens


class TestTruncation:
    def test_unfinished_requests_marked_truncated(self):
        model, params = family_model("dense")
        vocab = model.cfg.vocab_size
        reqs = make_requests(vocab, n=4)
        engine = ServeEngine(model, params, max_batch=2, max_len=32)
        engine.run(reqs, max_steps=2)
        n_trunc = sum(1 for r in reqs if r.truncated)
        assert n_trunc > 0
        assert engine.metrics["truncated"] == n_trunc
        for r in reqs:
            assert r.done != r.truncated  # exactly one of the two
        assert engine.telemetry.summary()["truncated"] == n_trunc

    def test_completed_run_has_no_truncations(self):
        model, params = family_model("dense")
        reqs = make_requests(model.cfg.vocab_size, n=2)
        engine = ServeEngine(model, params, max_batch=2, max_len=32)
        engine.run(reqs)
        assert engine.metrics["truncated"] == 0
        assert all(r.done and not r.truncated for r in reqs)


class TestPrefixCache:
    def test_hits_and_bit_identical_outputs(self):
        model, params = family_model("dense")
        vocab = model.cfg.vocab_size
        rng = np.random.default_rng(42)
        system = list(map(int, rng.integers(1, vocab, 8)))
        reqs = [Request(rid=i,
                        prompt=system + list(map(int,
                                                 rng.integers(1, vocab, 3))),
                        max_new_tokens=4)
                for i in range(3)]
        with_cache = copy.deepcopy(reqs)
        without = copy.deepcopy(reqs)
        e1 = ServeEngine(model, params, max_batch=2, max_len=32,
                         chunk_size=8, prefix_cache=True)
        e1.run(with_cache)
        e2 = ServeEngine(model, params, max_batch=2, max_len=32,
                         chunk_size=8)
        e2.run(without)
        assert e1.metrics["prefix_hits"] > 0
        assert e1.metrics["prefix_tokens_reused"] >= 8
        assert [r.out_tokens for r in with_cache] == \
            [r.out_tokens for r in without]

    def test_proper_prefix_only(self):
        pc = PrefixCache(block=2)
        snap = {"k": np.zeros((2, 2))}
        assert pc.put([1, 2, 3, 4], snap)
        n, _ = pc.match([1, 2, 3, 4])      # full prompt: no proper prefix
        assert n == 0
        n, got = pc.match([1, 2, 3, 4, 5])
        assert n == 4 and got is not None
        n, _ = pc.match([9, 9, 9, 9, 9])
        assert n == 0

    def test_alignment_and_lru_eviction(self):
        pc = PrefixCache(max_entries=2, block=4)
        snap = {"x": np.zeros((1,))}
        assert not pc.put([1, 2, 3], snap)          # unaligned: rejected
        assert pc.put([1, 2, 3, 4], snap)
        assert pc.put([5, 6, 7, 8], snap)
        assert pc.put([9, 10, 11, 12], snap)        # evicts the oldest
        assert len(pc) == 2
        assert pc.evictions == 1
        n, _ = pc.match([1, 2, 3, 4, 5])
        assert n == 0                                # evicted

    def test_peek_does_not_touch_stats(self):
        pc = PrefixCache(block=2)
        pc.put([1, 2], {"x": np.zeros((1,))})
        assert pc.peek_len([1, 2, 3]) == 2
        assert pc.hits == 0 and pc.misses == 0

    def test_interest_gating(self):
        """Unique prompts never trigger snapshots; shared ones do."""
        pc = PrefixCache(block=4)
        pc.register([1, 2, 3, 4, 5])
        assert not pc.wants([1, 2, 3, 4])      # one request: not shared
        pc.register([1, 2, 3, 4, 9])
        assert pc.wants([1, 2, 3, 4])          # two sharers
        assert not pc.wants([1, 2, 3, 9])
        # engine-level: a lone long prompt leaves the cache empty
        model, params = family_model("dense")
        vocab = model.cfg.vocab_size
        engine = ServeEngine(model, params, max_batch=2, max_len=32,
                             chunk_size=4, prefix_cache=True)
        engine.run(make_requests(vocab, n=2, prompt_len=12, max_new=2,
                                 seed=11))
        assert len(engine.prefix_cache) == 0
        assert engine.prefix_cache.insertions == 0


class TestScheduler:
    def _capacity(self):
        return SOLCapacityModel(get_arch("qwen2-0.5b").reduced(),
                                efficiency=0.5)

    def test_capacity_model_monotone(self):
        cap = self._capacity()
        base = cap.step_seconds(decode_positions=[8, 8])
        more_tokens = cap.step_seconds(decode_positions=[8, 8],
                                       prefill_tokens=64)
        longer_ctx = cap.step_seconds(decode_positions=[512, 512])
        assert more_tokens > base
        assert longer_ctx > base
        assert cap.step_seconds(decode_positions=[]) == 0.0

    def test_max_prefill_tokens_respects_budget(self):
        cap = self._capacity()
        t_one = cap.step_seconds(decode_positions=[8], prefill_tokens=8)
        n = cap.max_prefill_tokens(decode_positions=[8],
                                   budget_s=t_one * 2.5, granularity=8,
                                   cap=1024)
        assert n >= 8
        t_n = cap.step_seconds(decode_positions=[8], prefill_tokens=n)
        assert t_n <= t_one * 2.5

    def test_sol_scheduler_defers_past_capacity(self):
        cap = self._capacity()
        sched = SOLScheduler(cap, chunk_size=8)
        long_req = Request(rid=0, prompt=list(range(1, 9)) * 4,
                           max_new_tokens=2)
        sched.submit(long_req, slo="batch", step=0)
        # an interactive request is decoding with an impossibly tight ITL
        view = EngineView(free_slots=1, num_slots=2,
                          decode_positions=[16],
                          decode_slos=["interactive"], step=0)
        cap_big = SOLCapacityModel(get_arch("qwen2-0.5b").reduced(),
                                   efficiency=1e-12)
        sched_tight = SOLScheduler(cap_big, chunk_size=8)
        sched_tight.submit(long_req, slo="batch", step=0)
        assert sched_tight.next_admissions(view) == []      # deferred
        assert len(sched_tight) == 1
        # with no interactive decoder active, admission is unrestricted
        view_free = EngineView(free_slots=1, num_slots=2, step=0)
        assert len(sched.next_admissions(view_free)) == 1

    def test_sol_scheduler_priority_order(self):
        sched = SOLScheduler(self._capacity(), chunk_size=8)
        batch = Request(rid=0, prompt=[1, 2], max_new_tokens=1, slo="batch")
        inter = Request(rid=1, prompt=[3, 4], max_new_tokens=1,
                        slo="interactive")
        sched.submit(batch, slo="batch", step=0)
        sched.submit(inter, slo="interactive", step=0)
        out = sched.next_admissions(EngineView(free_slots=1, num_slots=1))
        assert [e.req.rid for e in out] == [1]   # interactive first

    def test_fifo_order_and_requeue(self):
        sched = FIFOScheduler()
        a = sched.submit(Request(rid=0, prompt=[1], max_new_tokens=1))
        sched.submit(Request(rid=1, prompt=[2], max_new_tokens=1))
        got = sched.next_admissions(EngineView(free_slots=1, num_slots=1))
        assert [e.req.rid for e in got] == [0]
        sched.requeue_front(a)
        got = sched.next_admissions(EngineView(free_slots=2, num_slots=2))
        assert [e.req.rid for e in got] == [0, 1]

    def test_sol_end_to_end(self):
        model, params = family_model("dense")
        reqs = make_requests(model.cfg.vocab_size, n=4)
        for r in reqs[:2]:
            r.slo = "interactive"
        engine = ServeEngine(model, params, max_batch=2, max_len=32,
                             chunk_size=8, scheduler="sol")
        engine.run(reqs)
        assert all(r.done for r in reqs)


class TestTunedCfgResolution:
    def test_dtype_key_follows_model_config(self, monkeypatch):
        """fp32 models must look up fp32 tuning entries, not bf16 ones."""
        import dataclasses
        from repro.models.model import Model
        from repro.core import tune
        from repro.serve.engine import resolve_tuned_decode_cfg

        seen = []

        def fake_attn(sq, skv, d, dtype, **kw):
            seen.append(dtype)
            return None

        def fake_ssd(t, n, p, dtype):
            seen.append(dtype)
            return None

        monkeypatch.setattr(tune, "tuned_attention_block", fake_attn)
        monkeypatch.setattr(tune, "tuned_ssd_chunk", fake_ssd)
        for family, dtype in (("dense", "fp32"), ("ssm", "bf16")):
            cfg = get_arch(ARCH_BY_FAMILY[family]).reduced()
            cfg = dataclasses.replace(cfg, compute_dtype=dtype)
            seen.clear()
            resolve_tuned_decode_cfg(Model(cfg), 64)
            assert seen and all(d == dtype for d in seen)

    def test_build_model_rejects_undeclared_compute_dtype(self):
        """A config declaring a dtype the substrate doesn't compute in must
        fail loudly instead of silently mis-keying tuning lookups."""
        import dataclasses
        cfg = dataclasses.replace(get_arch("qwen2-0.5b").reduced(),
                                  compute_dtype="fp32")
        with pytest.raises(NotImplementedError, match="compute_dtype"):
            build_model(cfg)


class TestStreaming:
    def test_events_match_outputs(self):
        model, params = family_model("dense")
        reqs = make_requests(model.cfg.vocab_size, n=3)
        engine = ServeEngine(model, params, max_batch=2, max_len=32,
                             chunk_size=8)
        events = list(engine.stream(copy.deepcopy(reqs)))
        groups = collect_streams(events)
        assert sorted(groups) == [0, 1, 2]
        for rid, evs in groups.items():
            assert [e.index for e in evs] == list(range(len(evs)))
            assert [e.final for e in evs[:-1]] == [False] * (len(evs) - 1)
            assert evs[-1].final
            steps = [e.step for e in evs]
            assert steps == sorted(steps)

    def test_mux_callbacks(self):
        model, params = family_model("dense")
        reqs = make_requests(model.cfg.vocab_size, n=2)
        engine = ServeEngine(model, params, max_batch=2, max_len=32,
                             chunk_size=8)
        seen = []
        engine.mux.subscribe(lambda ev: seen.append(ev.rid), rid=1)
        engine.run(reqs)
        assert set(seen) == {1}
        assert len(seen) == len(reqs[1].out_tokens)


class TestTelemetry:
    def test_percentile(self):
        assert percentile([1.0], 95) == 1.0
        assert percentile([1.0, 2.0, 3.0, 4.0], 50) == 2.5
        assert percentile([1.0, 2.0, 3.0, 4.0], 100) == 4.0
        assert np.isnan(percentile([], 50))

    def test_summary_fields(self):
        model, params = family_model("dense")
        reqs = make_requests(model.cfg.vocab_size, n=4)
        engine = ServeEngine(model, params, max_batch=2, max_len=32,
                             chunk_size=8)
        engine.run(reqs)
        s = engine.telemetry.summary()
        assert s["requests"] == 4 and s["completed"] == 4
        assert s["tokens"] == sum(len(r.out_tokens) for r in reqs)
        assert s["ttft_steps_p50"] <= s["ttft_steps_p95"]
        assert 0 < s["slot_utilization"] <= 1
        assert s["steps"] == engine.metrics["steps"]
