"""SOL-guided inter-stage fusion: golden tests.

Every fusion pattern must produce BITWISE-identical output to the unfused
driver (the pass replays the unfused materialization dtype round-trips at
each fold boundary), the pass must decline when VMEM pressure or missing
shape proof says so, and the fused kernels must match the jnp oracles.
"""

import numpy as np
import pytest

jnp = pytest.importorskip("jax.numpy")

from repro.core.codegen.fusion import fuse_pipeline
from repro.core.dsl import compile_dsl, lower_dsl

RNG = np.random.default_rng(7)


def _gemm(dt, chain=""):
    return (f"gemm().with_dtype(input={dt}, acc=fp32, output={dt})"
            f".with_tile(m=64, n=128, k=128)" + chain)


def _arrays(**specs):
    return {k: RNG.standard_normal(v).astype(np.float32)
            for k, v in specs.items()}


def _fused_unfused(src, arrays, fused_names, unfused_names, hints=None,
                   backend="pallas", fuse="auto"):
    kf = compile_dsl(src, backend, use_cache=False, fuse=fuse,
                     shape_hints=hints)
    ku = compile_dsl(src, backend, use_cache=False, fuse="off")
    assert tuple(kf.all_input_names) == tuple(fused_names)
    assert tuple(ku.all_input_names) == tuple(unfused_names)
    out_f = np.asarray(kf.fn(*[arrays[n] for n in fused_names]))
    out_u = np.asarray(ku.fn(*[arrays[n] for n in unfused_names]))
    return kf, ku, out_f, out_u


PATTERN_CASES = {
    # pattern -> (src template, specs, fused sig, unfused sig,
    #             unfused-name -> spec-name alias)
    "fold_eltwise": (
        lambda dt: ("pipeline(" + _gemm(dt, " >> bias()") + ", "
                    f"eltwise().with_dtype(input={dt}, acc=fp32,"
                    f" output={dt}) >> gelu() >> scale(value=2.0))"),
        dict(a=(48, 256), b=(256, 128), bias=(128,)),
        ("a", "b", "bias"), ("a", "b", "bias"), {}),
    "fold_rmsnorm": (
        lambda dt: ("pipeline(" + _gemm(dt, " >> bias() >> gelu()") + ", "
                    f"rmsnorm().with_dtype(input={dt}, acc=fp32,"
                    f" output={dt}))"),
        dict(a=(48, 256), b=(256, 128), bias=(128,), gamma=(128,)),
        ("a", "b", "bias", "gamma"), ("a", "b", "gamma_s1", "bias"),
        {"gamma_s1": "gamma"}),
    "rmsnorm_gemm": (
        lambda dt: (f"pipeline(rmsnorm().with_dtype(input={dt}, acc=fp32,"
                    f" output={dt}), " + _gemm(dt, " >> bias() >> silu()")
                    + ")"),
        dict(x=(48, 256), gamma=(256,), b=(256, 128), bias=(128,)),
        ("x", "gamma", "b", "bias"), ("x", "gamma", "b_s1", "bias_s1"),
        {"b_s1": "b", "bias_s1": "bias"}),
    "gemm_gemm": (
        lambda dt: ("pipeline(" + _gemm(dt, " >> bias() >> gelu()") + ", "
                    + _gemm(dt) + ")"),
        dict(a=(48, 256), b=(256, 128), bias=(128,), b2=(128, 128)),
        ("a", "b", "b2", "bias"), ("a", "b", "b_s1", "bias"),
        {"b_s1": "b2"}),
}


class TestGoldenBitwise:
    @pytest.mark.parametrize("dtype", ["fp32", "bf16"])
    @pytest.mark.parametrize("pattern", sorted(PATTERN_CASES))
    def test_fused_bitwise_matches_unfused(self, pattern, dtype):
        src_fn, specs, fsig, usig, alias = PATTERN_CASES[pattern]
        arrays = _arrays(**specs)
        for n in usig:                  # unfused aliases share the arrays
            if n not in arrays:
                arrays[n] = arrays[alias[n]]
        hints = {n: arrays[n].shape for n in usig}
        kf, ku, out_f, out_u = _fused_unfused(
            src_fn(dtype), arrays, fsig, usig, hints=hints)
        assert len(ku.ir.kernel_stages) == 2
        assert len(kf.ir.kernel_stages) == 1, \
            [d.reason for d in kf.fusion.decisions]
        assert out_f.dtype == out_u.dtype
        np.testing.assert_array_equal(out_f, out_u)

    def test_three_stage_acceptance_pipeline_single_dispatch(self):
        """transform -> gemm+bias_gelu -> rmsnorm == ONE fused dispatch,
        bitwise identical to the unfused driver."""
        src = ("pipeline(transpose(input, NCL, NCL, fp32, bf16), "
               + _gemm("bf16", " >> bias() >> gelu()") + ", "
               "rmsnorm().with_dtype(input=bf16, acc=fp32, output=bf16))")
        arrays = _arrays(a=(48, 256), b=(256, 128), bias=(128,),
                         gamma=(128,))
        arrays["gamma_s1"] = arrays["gamma"]
        hints = {n: arrays[n].shape
                 for n in ("a", "b", "gamma_s1", "bias")}
        kf, ku, out_f, out_u = _fused_unfused(
            src, arrays, ("a", "b", "bias", "gamma"),
            ("a", "b", "gamma_s1", "bias"), hints=hints)
        assert len(kf.ir.kernel_stages) == 1
        np.testing.assert_array_equal(out_f, out_u)
        rep = kf.fusion
        assert rep.fused_count == 1
        assert rep.decisions[0].pattern == "fold_rmsnorm"

    @pytest.mark.parametrize("backend", ["pallas", "xla"])
    @pytest.mark.parametrize("pattern",
                             ["fold_eltwise", "fold_rmsnorm",
                              "rmsnorm_gemm", "gemm_gemm"])
    def test_mixed_dtype_boundary_bitwise(self, pattern, backend):
        """Consumer input dtype != output dtype: the fold must replay each
        backend's OWN materialization round-trips (pallas kernels write at
        input dtype; XLA casts straight to the output dtype)."""
        mixed = {
            "fold_eltwise": ("pipeline(" + _gemm("bf16", " >> bias()")
                             + ", eltwise().with_dtype(input=bf16, acc=fp32,"
                             " output=fp32) >> gelu())"),
            "fold_rmsnorm": ("pipeline(" + _gemm("bf16", " >> bias()")
                             + ", rmsnorm().with_dtype(input=bf16, acc=fp32,"
                             " output=fp32))"),
            "rmsnorm_gemm": ("pipeline(rmsnorm().with_dtype(input=bf16,"
                             " acc=fp32, output=bf16), "
                             + _gemm("bf16").replace("output=bf16",
                                                     "output=fp32") + ")"),
            "gemm_gemm": ("pipeline(" + _gemm("bf16", " >> bias()") + ", "
                          + _gemm("bf16").replace("output=bf16",
                                                  "output=fp32") + ")"),
        }[pattern]
        _, specs, _, _, alias = PATTERN_CASES[pattern]
        arrays = _arrays(**specs)

        def resolve(name):
            return arrays[alias.get(name, name)] if name not in arrays \
                else arrays[name]

        ku = compile_dsl(mixed, backend, use_cache=False, fuse="off")
        hints = {n: resolve(n).shape for n in ku.all_input_names}
        kf = compile_dsl(mixed, backend, use_cache=False, fuse="auto",
                         shape_hints=hints)
        assert len(kf.ir.kernel_stages) == 1, \
            [d.reason for d in kf.fusion.decisions]
        out_f = np.asarray(kf.fn(*[resolve(n)
                                   for n in kf.all_input_names]))
        out_u = np.asarray(ku.fn(*[resolve(n)
                                   for n in ku.all_input_names]))
        assert out_f.dtype == out_u.dtype == np.float32
        np.testing.assert_array_equal(out_f, out_u)

    def test_xla_backend_agrees(self):
        src_fn, specs, fsig, usig, alias = PATTERN_CASES["gemm_gemm"]
        arrays = _arrays(**specs)
        for n in usig:
            if n not in arrays:
                arrays[n] = arrays[alias[n]]
        hints = {n: arrays[n].shape for n in usig}
        kf, ku, out_f, out_u = _fused_unfused(
            src_fn("fp32"), arrays, fsig, usig, hints=hints, backend="xla")
        np.testing.assert_array_equal(out_f, out_u)


class TestDecisions:
    def test_report_records_bytes_and_headroom(self):
        src_fn, specs, fsig, usig, alias = PATTERN_CASES["fold_rmsnorm"]
        hints = {n: specs[alias.get(n, n)] for n in usig}
        k = compile_dsl(src_fn("bf16"), "pallas", use_cache=False,
                        fuse="auto", shape_hints=hints)
        d = k.fusion.decisions[0]
        assert d.fused and d.pattern == "fold_rmsnorm"
        # intermediate (48, 128) bf16: one write + one read
        assert d.bytes_saved == 2 * 48 * 128 * 2
        assert 0 < d.headroom < 1
        assert k.fusion.bytes_saved == d.bytes_saved
        assert k.fusion.as_dict()["fused_count"] == 1

    def test_vmem_pressure_declines(self):
        """The pass must *decline* when the fused working set exceeds VMEM."""
        src = ("pipeline(rmsnorm().with_dtype(input=bf16, acc=fp32,"
               " output=bf16), " + _gemm("bf16") + ")")
        hints = {"x": (8192, 1 << 19), "gamma": (1 << 19,),
                 "b_s1": (1 << 19, 8192)}
        k = compile_dsl(src, "pallas", use_cache=False, fuse="auto",
                        shape_hints=hints)
        assert len(k.ir.kernel_stages) == 2
        d = k.fusion.decisions[0]
        assert not d.fused
        assert "VMEM pressure" in d.reason
        assert d.vmem_bytes is not None

    def test_no_hints_declines_vmem_patterns_but_folds(self):
        src = ("pipeline(" + _gemm("bf16", " >> bias()") + ", "
               + _gemm("bf16") + ")")
        k = compile_dsl(src, "pallas", use_cache=False, fuse="auto")
        assert len(k.ir.kernel_stages) == 2
        assert "shape_hints" in k.fusion.decisions[0].reason
        # force fuses anyway
        k = compile_dsl(src, "pallas", use_cache=False, fuse="force")
        assert len(k.ir.kernel_stages) == 1

    def test_fuse_off_escape_hatch(self, monkeypatch):
        src_fn = PATTERN_CASES["fold_eltwise"][0]
        k = compile_dsl(src_fn("fp32"), "pallas", use_cache=False,
                        fuse="off")
        assert len(k.ir.kernel_stages) == 2
        assert k.fusion.mode == "off" and k.fusion.fused_count == 0
        monkeypatch.setenv("REPRO_FUSION", "off")
        k = compile_dsl(src_fn("fp32"), "pallas", use_cache=False)
        assert len(k.ir.kernel_stages) == 2

    def test_fused_namespace_differs_from_unfused(self):
        src_fn = PATTERN_CASES["fold_eltwise"][0]
        kf = compile_dsl(src_fn("fp32"), "pallas", use_cache=False)
        ku = compile_dsl(src_fn("fp32"), "pallas", use_cache=False,
                         fuse="off")
        assert kf.namespace != ku.namespace

    def test_tuning_cache_vetoes_edge(self, tmp_path, monkeypatch):
        """Fusion is a tunable axis: a measured {"fuse": false} record
        turns the edge off in auto mode."""
        monkeypatch.setenv("REPRO_TUNE_DIR", str(tmp_path))
        monkeypatch.delenv("REPRO_TUNE_DISABLE", raising=False)
        from repro.core import tune
        src_fn, specs, fsig, usig, alias = PATTERN_CASES["rmsnorm_gemm"]
        hints = {n: specs[alias.get(n, n)] for n in usig}
        dims = tuple(specs["x"]) + (specs["b"][1],)
        tune.record_fusion_measurement("rmsnorm_gemm", dims, "bf16",
                                       fuse_best=False)
        assert tune.tuned_fusion("rmsnorm_gemm", dims, "bf16") is False
        k = compile_dsl(src_fn("bf16"), "pallas", use_cache=False,
                        fuse="auto", shape_hints=hints)
        assert len(k.ir.kernel_stages) == 2
        assert "autotuner" in k.fusion.decisions[0].reason


class TestSignatureDedup:
    def test_repeated_aux_names_deduped(self):
        """Two bias() epilogues must not shadow each other in the driver."""
        src = _gemm("fp32", " >> bias() >> gelu() >> bias()")
        k = compile_dsl(src, "pallas", use_cache=False)
        assert k.all_input_names == ("a", "b", "bias", "bias__2")
        a = RNG.standard_normal((32, 128)).astype(np.float32)
        b = RNG.standard_normal((128, 128)).astype(np.float32)
        b1 = RNG.standard_normal((128,)).astype(np.float32)
        b2 = RNG.standard_normal((128,)).astype(np.float32)
        out = np.asarray(k(a, b, b1, b2))
        import jax
        ref = np.asarray(
            jax.nn.gelu(a @ b + b1[None, :], approximate=True) + b2[None, :])
        np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)

    def test_custom_input_named_like_primary_deduped(self):
        """A custom-epilogue input named like a primary operand must not
        shadow it in the generated signature."""
        src = ("gemm().with_dtype(input=fp32, acc=fp32, output=fp32)"
               ".with_tile(m=64, n=128, k=128).with_arch(tpu_v5p)"
               " >> custom('x * b', inputs={'b': 'col_vector'})")
        k = compile_dsl(src, "pallas", use_cache=False)
        assert k.all_input_names == ("a", "b", "b__2")
        a = RNG.standard_normal((32, 128)).astype(np.float32)
        b = RNG.standard_normal((128, 128)).astype(np.float32)
        scale = RNG.standard_normal((128,)).astype(np.float32)
        out = np.asarray(k(a, b, scale))
        np.testing.assert_allclose(out, (a @ b) * scale[None, :],
                                   rtol=2e-4, atol=2e-4)

    def test_pipeline_cross_stage_dedup(self):
        """The same aux name in two pipeline stages gets distinct driver
        parameters (the old code emitted shadowing duplicates)."""
        src = ("pipeline(" + _gemm("fp32", " >> bias()") + ", "
               + _gemm("fp32", " >> bias()") + ")")
        k = compile_dsl(src, "pallas", use_cache=False, fuse="off")
        names = k.all_input_names
        assert len(set(names)) == len(names)
        assert "bias" in names and "bias_s1" in names


class TestFusedKernelOracles:
    def test_rmsnorm_gemm_matches_ref(self):
        from repro.kernels import ops, ref
        x = RNG.standard_normal((40, 192)).astype(np.float32)
        g = RNG.standard_normal((192,)).astype(np.float32)
        b = RNG.standard_normal((192, 96)).astype(np.float32)
        out = np.asarray(ops.rmsnorm_gemm(
            jnp.asarray(x), jnp.asarray(g), jnp.asarray(b),
            tile=(64, 128, 128), eps=1e-6, out_dtype=jnp.float32))
        want = np.asarray(ref.rmsnorm_gemm_ref(
            jnp.asarray(x), jnp.asarray(g), jnp.asarray(b),
            out_dtype=jnp.float32))
        np.testing.assert_allclose(out, want, rtol=2e-4, atol=2e-4)

    def test_gemm_gemm_matches_ref(self):
        from repro.kernels import ops, ref
        a = RNG.standard_normal((40, 160)).astype(np.float32)
        b = RNG.standard_normal((160, 96)).astype(np.float32)
        b2 = RNG.standard_normal((96, 112)).astype(np.float32)
        out = np.asarray(ops.gemm_gemm(
            jnp.asarray(a), jnp.asarray(b), jnp.asarray(b2),
            tile=(64, 128, 128), k2_chunk=128, out_dtype=jnp.float32))
        want = np.asarray(ref.gemm_gemm_ref(
            jnp.asarray(a), jnp.asarray(b), jnp.asarray(b2),
            out_dtype=jnp.float32))
        np.testing.assert_allclose(out, want, rtol=2e-4, atol=2e-4)


class TestServeFusedDecode:
    def test_fused_decode_identical_and_fewer_dispatches(self):
        import jax
        from repro.configs import get_arch
        from repro.models.model import build_model
        import dataclasses
        cfg = get_arch("qwen2-0.5b").reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        fused = dataclasses.replace(model,
                                    cfg=dataclasses.replace(
                                        cfg, fused_decode=True))
        assert fused.decode_dispatch_count() < model.decode_dispatch_count()
        cache_a = model.init_cache(2, 32)
        cache_b = fused.init_cache(2, 32)
        toks = jnp.asarray([[3, 5, 7, 2], [11, 2, 4, 9]], jnp.int32)
        counts = jnp.asarray([4, 3], jnp.int32)
        la, ca = model.prefill_step(params, cache_a, toks, counts)
        lb, cb = fused.prefill_step(params, cache_b, toks, counts)
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
        for x, y in zip(jax.tree.leaves(ca), jax.tree.leaves(cb)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
