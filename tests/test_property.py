"""Hypothesis property tests on the system's invariants."""

import math

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.dsl import compile_dsl, lower_dsl, namespace_of, validate_dsl
from repro.core.schedule import (SchedulePolicy, UNSOLVED_FLOOR, fastp,
                                 geomean, replay_problem)
from repro.core.agent.runlog import Attempt, RunLog
from repro.core.sol.hardware import SUBLANE_MULTIPLE, TPU_V5E
from repro.core.sol.roofline import roofline

# ---------------------------------------------------------------------------
# DSL: every config sampled from the valid grammar space validates + lowers
# ---------------------------------------------------------------------------

valid_m = st.sampled_from([16, 32, 64, 128, 256, 512])
valid_nk = st.sampled_from([128, 256, 512, 1024])
dtypes = st.sampled_from(["fp32", "bf16"])
acts = st.sampled_from(["relu", "gelu", "silu", "tanh", "sigmoid"])


@settings(max_examples=60, deadline=None)
@given(m=valid_m, n=valid_nk, k=valid_nk, dt=dtypes, stages=st.integers(1, 4),
       act=acts)
def test_valid_gemm_space_always_validates(m, n, k, dt, stages, act):
    sub = SUBLANE_MULTIPLE[dt]
    m = max(m, sub) // sub * sub
    src = (f"gemm().with_dtype(input={dt}, acc=fp32, output={dt})"
           f".with_tile(m={m}, n={n}, k={k}).with_stages({stages})"
           f" >> {act}()")
    diags = validate_dsl(src)
    vmem = [d for d in diags if d.code == "E_TILE_VMEM"]
    others = [d for d in diags if d.code != "E_TILE_VMEM"]
    assert not others, others
    if not vmem:
        ir, _ = lower_dsl(src)
        assert namespace_of(ir).startswith("upallas_")


@settings(max_examples=40, deadline=None)
@given(n=st.integers(1, 2048))
def test_misaligned_lane_always_caught(n):
    src = (f"gemm().with_dtype(input=fp32, acc=fp32, output=fp32)"
           f".with_tile(m=64, n={n}, k=128)")
    diags = validate_dsl(src)
    if n % 128 == 0:
        assert not any(d.code == "E_TILE_LANE" for d in diags)
    else:
        assert any(d.code == "E_TILE_LANE" for d in diags)


@settings(max_examples=30, deadline=None)
@given(dt=dtypes, m=valid_m, n=valid_nk, k=valid_nk)
def test_namespace_is_pure_function_of_config(dt, m, n, k):
    sub = SUBLANE_MULTIPLE[dt]
    m = max(m, sub) // sub * sub
    src = (f"gemm().with_dtype(input={dt}, acc=fp32, output={dt})"
           f".with_tile(m={m}, n={n}, k={k})")
    if validate_dsl(src):
        return
    ir1, _ = lower_dsl(src)
    ir2, _ = lower_dsl(src + "  # comment\n")
    assert namespace_of(ir1) == namespace_of(ir2)


# ---------------------------------------------------------------------------
# Roofline invariants
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(flops=st.floats(1e6, 1e18), bytes_=st.floats(1e3, 1e15),
       coll=st.floats(0, 1e14), chips=st.sampled_from([1, 8, 256, 512]))
def test_roofline_terms_positive_and_sol_is_max(flops, bytes_, coll, chips):
    r = roofline(flops, bytes_, collective_bytes=coll, num_chips=chips)
    assert r.t_compute > 0 and r.t_memory > 0 and r.t_collective >= 0
    assert math.isclose(r.t_sol,
                        max(r.t_compute, r.t_memory, r.t_collective))
    assert r.bottleneck in ("compute", "memory", "collective")
    # more chips never increases any term
    r2 = roofline(flops, bytes_, collective_bytes=coll, num_chips=chips * 2)
    assert r2.t_sol <= r.t_sol + 1e-12


@settings(max_examples=30, deadline=None)
@given(flops=st.floats(1e6, 1e15), bytes_=st.floats(1e3, 1e12))
def test_gap_and_fraction_are_inverse(flops, bytes_):
    r = roofline(flops, bytes_)
    t = r.t_sol * 3.7
    assert math.isclose(r.gap(t) * r.fraction_of_roofline(t), 1.0,
                        rel_tol=1e-9)


# ---------------------------------------------------------------------------
# Metrics invariants
# ---------------------------------------------------------------------------

speedups = st.lists(st.floats(0, 32, allow_nan=False), min_size=1,
                    max_size=59)


@settings(max_examples=50, deadline=None)
@given(sp=speedups)
def test_fastp_monotone_decreasing_in_r(sp):
    rs = [0.5, 1.0, 2.0, 4.0, 8.0]
    vals = [fastp(sp, r) for r in rs]
    assert all(a >= b for a, b in zip(vals, vals[1:]))
    assert all(0.0 <= v <= 1.0 for v in vals)


@settings(max_examples=50, deadline=None)
@given(sp=speedups)
def test_geomean_bounds(sp):
    g = geomean(sp)
    hi = max(max(sp), UNSOLVED_FLOOR)
    assert UNSOLVED_FLOOR - 1e-12 <= g <= hi + 1e-9


# ---------------------------------------------------------------------------
# Scheduler replay invariants
# ---------------------------------------------------------------------------

def _mk_log(speedups, t_ref=1.0, t_sol=0.2):
    attempts = [
        Attempt(index=i, phase="implement", description="", tokens=1000,
                ok=True, runtime_s=t_ref / s if s > 0 else float("inf"),
                speedup=s, label="no_issues")
        for i, s in enumerate(speedups)
    ]
    return RunLog(problem_id="p", variant="v", capability="mid", seed=0,
                  t_ref=t_ref, t_sol=t_sol, t_sol_ceiling=t_sol,
                  attempts=attempts)


@settings(max_examples=60, deadline=None)
@given(sp=st.lists(st.floats(0.1, 10), min_size=1, max_size=40),
       eps=st.one_of(st.none(), st.floats(0.1, 3.0)),
       w=st.sampled_from([0, 2, 4, 8]))
def test_replay_never_exceeds_budget_and_retention_le_1(sp, eps, w):
    log = _mk_log(sp)
    r = replay_problem(log, SchedulePolicy(eps, w))
    assert 1 <= r.stop_attempt <= r.total_attempts
    assert r.tokens_used <= r.tokens_full
    assert r.best_speedup <= r.best_speedup_full + 1e-9


@settings(max_examples=30, deadline=None)
@given(sp=st.lists(st.floats(0.1, 10), min_size=1, max_size=40))
def test_replay_no_policy_is_identity(sp):
    log = _mk_log(sp)
    r = replay_problem(log, SchedulePolicy(None, 0))
    assert r.stop_attempt == r.total_attempts
    assert r.tokens_used == r.tokens_full
    assert r.best_speedup == r.best_speedup_full
