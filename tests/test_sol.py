"""SOL engine: characterization, roofline, HLO analysis, reports."""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core.sol import (Characterization, attention_flops, gemm_flops,
                            gemm_op, get_chip, make_report,
                            parse_collective_bytes, roofline,
                            summarize_compiled, TPU_V5E)
from repro.core.sol.characterize import TensorSpec


def test_gemm_characterization_matches_paper_example():
    """Paper A.2: 4096^3 fp32 GEMM -> 1.374e11 FLOPs, 2.013e8 bytes."""
    ch = Characterization("L1/1", [gemm_op(4096, 4096, 4096)])
    assert np.isclose(ch.total_flops, 1.374e11, rtol=1e-3)
    assert np.isclose(ch.best_case_bytes, 2.013e8, rtol=1e-3)
    assert np.isclose(ch.arithmetic_intensity, 682.6, rtol=1e-2)


def test_h100_report_matches_paper_numbers():
    """Paper A.2 on H100: t_SOL ~ 0.367 ms TF32, ~0.183 ms FP16."""
    ch = Characterization("L1/1", [gemm_op(4096, 4096, 4096)])
    rep = make_report("L1/1", ch, chip=get_chip("h100"))
    assert np.isclose(rep.steering.t_compute, 0.367e-3, rtol=2e-2)
    assert np.isclose(rep.ceiling.t_compute, 0.1834e-3, rtol=2e-2)
    assert rep.steering.bottleneck == "compute"


def test_v5e_ridge_point():
    chip = TPU_V5E
    assert np.isclose(chip.ridge_point, 197e12 / 819e9, rtol=1e-6)


def test_causal_attention_half_flops():
    full = attention_flops(1, 1024, 1024, 8, 64, causal=False)
    causal = attention_flops(1, 1024, 1024, 8, 64, causal=True)
    assert np.isclose(causal, full / 2, rtol=1e-6)


def test_fused_bytes_less_than_unfused():
    ops = [gemm_op(512, 512, 512)]
    fused = Characterization("p", ops, fused=True)
    unfused = Characterization("p", ops, fused=False)
    assert fused.best_case_bytes <= unfused.best_case_bytes


def test_report_markdown_structure():
    ch = Characterization("demo", [gemm_op(1024, 1024, 1024)])
    md = make_report("demo", ch).to_markdown()
    for section in ("Problem Characterization", "Hardware Limits",
                    "Theoretical Minimum Time", "Roofline Analysis",
                    "Structured JSON Output"):
        assert section in md
    js = make_report("demo", ch).to_json()
    assert js["theoretical_runtime_s_ceiling"] <= js["theoretical_runtime_s"]


def test_parse_collective_bytes_from_real_hlo():
    mesh = jax.make_mesh((1,), ("x",))
    from jax.sharding import NamedSharding, PartitionSpec as P

    def f(a):
        return a.sum()

    lowered = jax.jit(
        f, in_shardings=NamedSharding(mesh, P("x")),
        out_shardings=NamedSharding(mesh, P())).lower(
            jax.ShapeDtypeStruct((128, 128), jnp.float32))
    compiled = lowered.compile()
    stats = parse_collective_bytes(compiled.as_text())
    # single-device: no collectives; parser must return cleanly
    assert stats.total_bytes >= 0


def test_parse_collective_bytes_synthetic():
    hlo = """
  %param.1 = f32[1024,512]{1,0} parameter(0)
  %all-reduce.3 = f32[1024,512]{1,0} all-reduce(%param.1), channel_id=1
  %ag = bf16[2048,512]{1,0} all-gather(%param.1), dimensions={0}
  %done = f32[8]{0} all-reduce-done(%all-reduce.3)
"""
    stats = parse_collective_bytes(hlo)
    assert stats.count_by_opcode["all-reduce"] == 1
    assert stats.count_by_opcode["all-gather"] == 1
    # operand sizes: both consume %param.1 = 1024*512*4 bytes
    assert stats.bytes_by_opcode["all-reduce"] == 1024 * 512 * 4
    assert stats.bytes_by_opcode["all-gather"] == 1024 * 512 * 4


def test_summarize_compiled_on_cpu():
    def f(a, b):
        return jnp.dot(a, b)

    lowered = jax.jit(f).lower(
        jax.ShapeDtypeStruct((256, 256), jnp.float32),
        jax.ShapeDtypeStruct((256, 256), jnp.float32))
    summ = summarize_compiled(lowered.compile(), num_devices=1)
    assert summ.per_device_flops >= 2 * 256 ** 3 * 0.99
    assert summ.total_flops == summ.per_device_flops


def test_loop_scaled_cost_scan():
    """XLA counts while bodies once; the loop-aware parser must scale."""
    from repro.core.sol.hlo_analysis import loop_scaled_cost

    def scanned(x, ws):
        def body(c, w):
            return jnp.dot(c, w), None
        c, _ = jax.lax.scan(body, x, ws)
        return c

    compiled = jax.jit(scanned).lower(
        jax.ShapeDtypeStruct((128, 128), jnp.float32),
        jax.ShapeDtypeStruct((12, 128, 128), jnp.float32)).compile()
    sc = loop_scaled_cost(compiled.as_text())
    assert np.isclose(sc.gamma, 12.0, rtol=0.05)
    assert np.isclose(sc.dot_flops_scaled, 12 * 2 * 128 ** 3, rtol=0.05)
