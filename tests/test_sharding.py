"""Distributed SOL + sharding lever tests.

In-process tests cover the pure layers (rules fallbacks with a stub mesh,
the collective cost model, validator gating, the shard tuning axis, the
compile artifact).  Anything that must RUN on a multi-device mesh goes
through a subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_
count=N`` set before jax imports (the main pytest process may be pinned
to one device).
"""

import os
import subprocess
import sys
from types import SimpleNamespace

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_forced(script: str, n_devices: int) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={n_devices}"
    env["REPRO_PALLAS_INTERPRET"] = "1"
    res = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=REPO)
    assert res.returncode == 0, res.stdout + res.stderr
    return res.stdout


def _mesh(**axes):
    """A stub with the Mesh attributes the rule functions read — the
    fallback paths are pure spec math, no devices needed."""
    return SimpleNamespace(shape=dict(axes), axis_names=tuple(axes))


# ---------------------------------------------------------------------------
# sharding.rules fallback paths (previously untested)
# ---------------------------------------------------------------------------

class TestRulesFallbacks:
    def test_nondivisible_dims_replicate(self):
        from jax.sharding import PartitionSpec as P
        from repro.sharding.rules import param_spec

        spec = param_spec("mlp/w_up", (6, 10), _mesh(data=2, model=4))
        assert spec == P(None, None)

    def test_next_candidate_dim_tried(self):
        from repro.sharding.rules import param_spec

        # largest dim (510) not divisible by model=4; next (256) is
        spec = param_spec("mlp/w_up", (256, 510), _mesh(data=2, model=4))
        assert tuple(spec) == ("model", None)

    def test_scan_stacked_leading_dims_unsharded(self):
        from repro.sharding.rules import param_spec

        for path, stacked in (("layers/attn/wq", 1),
                              ("ssm_layers/mamba/w_in", 2)):
            shape = (8,) * stacked + (256, 512)
            spec = param_spec(path, shape, _mesh(data=2, model=4))
            assert all(s is None for s in tuple(spec)[:stacked]), \
                (path, tuple(spec))
            assert "model" in tuple(spec)

    def test_fsdp_threshold_respected(self):
        from repro.sharding.rules import FSDP_MIN_SIZE, param_spec

        mesh = _mesh(data=2, model=4)
        small = param_spec("mlp/w_up", (256, 512), mesh)      # 128Ki elems
        assert "data" not in tuple(small)
        assert (256 * 512) < FSDP_MIN_SIZE
        big = param_spec("mlp/w_up", (1024, 2048), mesh)      # 2Mi elems
        assert "model" in tuple(big) and "data" in tuple(big)

    def test_fsdp_skips_embeddings(self):
        from repro.sharding.rules import param_spec

        spec = param_spec("embed", (4096, 1024), _mesh(data=2, model=4))
        assert "data" not in tuple(spec)
        assert "model" in tuple(spec)

    def test_fsdp_off_flag(self):
        from repro.sharding.rules import param_spec

        spec = param_spec("mlp/w_up", (1024, 2048),
                          _mesh(data=2, model=4), fsdp=False)
        assert "data" not in tuple(spec)

    def test_batch_spec_nondivisible_replicates(self):
        from jax.sharding import PartitionSpec as P
        from repro.sharding.rules import batch_spec

        assert batch_spec((3, 16), _mesh(data=2, model=2)) == P(None, None)

    def test_cache_spec_sequence_parallel_fallback(self):
        from repro.sharding.rules import cache_spec

        # batch (1) can't shard over data=2 -> the long seq dim shards
        spec = cache_spec("layers/k", (4, 1, 1024, 8, 64),
                          _mesh(data=2, model=2))
        assert tuple(spec)[2] == "data"

    def test_axis_size_shared_helper(self):
        from repro.core.sol.hardware import mesh_axis_size

        m = _mesh(data=2, model=4)
        assert mesh_axis_size(m, "model") == 4
        assert mesh_axis_size(m, "stage") == 1


# ---------------------------------------------------------------------------
# core.sol.collectives — the distributed cost model
# ---------------------------------------------------------------------------

class TestCollectiveModel:
    def test_wire_bytes_formulas(self):
        from repro.core.sol.collectives import wire_bytes

        payload = 1024.0
        assert wire_bytes("all_gather", payload, 4) == payload * 3 / 4
        assert wire_bytes("reduce_scatter", payload, 4) == payload * 3 / 4
        assert wire_bytes("all_reduce", payload, 4) == 2 * payload * 3 / 4
        assert wire_bytes("all_to_all", payload, 4) == payload * 3 / 16
        assert wire_bytes("all_gather", payload, 1) == 0.0

    def test_collective_cost_alpha_beta(self):
        from repro.core.sol.collectives import collective_cost
        from repro.core.sol.hardware import TPU_V5E

        c = collective_cost("all_gather", 1 << 20, 4, chip=TPU_V5E)
        assert c.steps == 3
        beta = c.wire_bytes / TPU_V5E.ici_bandwidth
        assert c.seconds == pytest.approx(3 * TPU_V5E.ici_latency + beta)
        assert c.total_wire_bytes == pytest.approx(4 * c.wire_bytes)

    def test_plan_picks_min_wire_strategy(self):
        from repro.core.sol.collectives import plan_tp_gemm

        # decode-skinny M: the C gather is tiny -> column wins
        p = plan_tp_gemm(8, 256, 1024, tp=4, a_dtype="bf16")
        assert p.strategy == "column"
        # huge M with an int8 weight: gathering 1 B/elem weight wins
        q = plan_tp_gemm(4096, 256, 1024, tp=4, a_dtype="bf16",
                         w_dtype="int8")
        assert q.strategy == "gather_w"
        # the quantized gather moves 4x fewer bytes than its fp32 twin
        fp = plan_tp_gemm(4096, 256, 1024, tp=4, a_dtype="bf16",
                          w_dtype="fp32", strategy="gather_w")
        assert fp.wire_bytes == pytest.approx(4 * q.wire_bytes)

    def test_divisibility_reported(self):
        from repro.core.sol.collectives import plan_tp_gemm

        p = plan_tp_gemm(8, 130, 1024, tp=4, strategy="column",
                         a_dtype="bf16")
        assert not p.shardable

    def test_tp_roofline_flags_collective_bound(self):
        from repro.core.sol.collectives import tp_matmul_roofline

        # tiny matmul over many chips: wire dominates
        res, plan = tp_matmul_roofline(8, 128, 128, tp=8, a_dtype="bf16")
        assert res.bottleneck == "collective"
        assert res.collective_bound
        # big compute-heavy matmul on few chips: compute dominates
        res2, _ = tp_matmul_roofline(8192, 8192, 8192, tp=2,
                                     a_dtype="bf16")
        assert not res2.collective_bound

    def test_decode_wire_bytes_per_step(self):
        from repro.configs import get_arch
        from repro.core.sol.collectives import decode_wire_bytes_per_step

        cfg = get_arch("qwen2-0.5b").reduced()
        assert decode_wire_bytes_per_step(cfg, tp=1) == 0.0
        w2 = decode_wire_bytes_per_step(cfg, tp=2, batch=4)
        w4 = decode_wire_bytes_per_step(cfg, tp=4, batch=4)
        assert 0 < w2 < w4      # more shards -> more bytes on the wire


# ---------------------------------------------------------------------------
# DSL validator gating
# ---------------------------------------------------------------------------

def _codes(src):
    from repro.core.dsl.compiler import validate_dsl

    return {d.code for d in validate_dsl(src)}


class TestValidatorSharding:
    DT = ".with_dtype(input=bf16, acc=fp32, output=bf16)"

    def test_valid_sharding_accepted(self):
        assert _codes(f"gemm(){self.DT}.with_sharding(tp=4)") == set()
        assert _codes(
            f"gemm(){self.DT}.with_sharding(tp=2, axis=data)") == set()

    def test_tp_zero_rejected(self):
        assert "E_SHARD_TP" in _codes(
            f"gemm(){self.DT}.with_sharding(tp=0)")

    def test_unknown_axis_rejected(self):
        assert "E_SHARD_AXIS" in _codes(
            f"gemm(){self.DT}.with_sharding(tp=2, axis=ring)")

    def test_non_gemm_rejected(self):
        assert "E_SHARD_OP" in _codes(
            f"batched_gemm(){self.DT}.with_sharding(tp=2)")

    def test_non_matmul_family_rejected(self):
        codes = _codes(
            "attention(causal=true)" + self.DT + ".with_sharding(tp=2)")
        assert "E_CFG_FAMILY" in codes

    def test_swap_conflict(self):
        assert "E_SHARD_SWAP" in _codes(
            "gemm().with_dtype(input=fp32, acc=fp32, output=fp32)"
            ".with_swap(true).with_sharding(tp=2)")

    def test_split_k_conflict(self):
        assert "E_SHARD_SPLITK" in _codes(
            f"gemm(){self.DT}"
            ".with_split_k(mode=serial, slices=2).with_sharding(tp=2)")

    def test_row_stat_epilogue_conflict(self):
        assert "E_SHARD_ROWSTAT" in _codes(
            f"gemm(){self.DT}.with_sharding(tp=2) >> rmsnorm()")

    def test_tp1_is_noop(self):
        from repro.core.dsl.compiler import lower_dsl

        ir, _ = lower_dsl(f"gemm(){self.DT}.with_sharding(tp=1)")
        base, _ = lower_dsl(f"gemm(){self.DT}")
        assert ir.tp == 1
        assert ir.canonical() == base.canonical()

    def test_tp_in_namespace(self):
        from repro.core.dsl.compiler import lower_dsl
        from repro.core.dsl.ir import namespace_of

        ir, _ = lower_dsl(f"gemm(){self.DT}.with_sharding(tp=4)")
        base, _ = lower_dsl(f"gemm(){self.DT}")
        assert "tp=4@model" in ir.canonical()
        assert namespace_of(ir) != namespace_of(base)


# ---------------------------------------------------------------------------
# Compile artifact: the distributed roofline lands on CompiledKernel
# ---------------------------------------------------------------------------

class TestShardingReport:
    SRC = ("gemm().with_dtype(input=bf16, acc=fp32, output=bf16)"
           ".with_sharding(tp=4)")

    def test_report_with_hints(self):
        from repro.core.dsl.compiler import compile_dsl

        ck = compile_dsl(self.SRC, "pallas",
                         shape_hints={"a": (8, 1024), "b": (1024, 512)})
        assert ck.sharding is not None and ck.sharding.max_tp == 4
        d = ck.sharding.decisions[0]
        assert d.strategy in ("column", "gather_w")
        assert d.wire_bytes and d.wire_bytes > 0
        # all three bounds recorded side by side
        assert d.t_compute is not None and d.t_memory is not None \
            and d.t_collective is not None
        assert d.bottleneck in ("compute", "memory", "collective")

    def test_cache_hit_keeps_sol_bounds(self):
        from repro.core.dsl.compiler import compile_dsl

        src = ("gemm().with_dtype(input=bf16, acc=fp32, output=bf16)"
               ".with_sharding(tp=2).with_tile(m=64, n=256, k=256)")
        with_hints = compile_dsl(
            src, "pallas", shape_hints={"a": (8, 256), "b": (256, 512)})
        assert with_hints.sharding.decisions[0].wire_bytes is not None
        # a hint-less recompile hits the cache and must NOT downgrade the
        # bounds-filled report
        without = compile_dsl(src, "pallas")
        assert without.sharding.decisions[0].wire_bytes is not None

    def test_report_without_hints(self):
        from repro.core.dsl.compiler import compile_dsl

        ck = compile_dsl(self.SRC, "xla")
        assert ck.sharding is not None
        d = ck.sharding.decisions[0]
        assert d.tp == 4 and d.wire_bytes is None

    def test_unsharded_has_no_report(self):
        from repro.core.dsl.compiler import compile_dsl

        ck = compile_dsl(
            "gemm().with_dtype(input=bf16, acc=fp32, output=bf16)",
            "pallas")
        assert ck.sharding is None

    def test_generated_source_routes_tp(self):
        from repro.core.dsl.compiler import compile_dsl

        ck = compile_dsl(self.SRC, "pallas")
        assert "tp_gemm" in ck.source and "tp=4" in ck.source
        ck_x = compile_dsl(self.SRC, "xla")
        assert "xla_tp_gemm" in ck_x.source

    def test_sharded_quantized_source(self):
        from repro.core.dsl.compiler import compile_dsl

        ck = compile_dsl(
            "gemm().with_dtype(input=bf16, acc=fp32, output=bf16)"
            ".with_wdtype(int8).with_sharding(tp=2)", "pallas")
        assert "tp_gemm_q" in ck.source

    def test_fusion_declines_sharded_edges(self):
        from repro.core.dsl.compiler import compile_dsl

        src = """pipeline(
  rmsnorm().with_dtype(input=fp32, acc=fp32, output=fp32),
  gemm().with_dtype(input=fp32, acc=fp32, output=fp32).with_sharding(tp=2))
"""
        ck = compile_dsl(src, "pallas",
                         shape_hints={"x": (32, 128), "gamma": (128,),
                                      "b_s1": (128, 256)})
        assert ck.fusion is not None and ck.fusion.fused_count == 0
        assert any("sharded" in d.reason for d in ck.fusion.decisions)
        assert ck.sharding is not None and ck.sharding.max_tp == 2


# ---------------------------------------------------------------------------
# shard:<op> tuning axis
# ---------------------------------------------------------------------------

class TestShardTuneAxis:
    def test_candidates_are_mesh_divisors(self):
        from repro.core import tune

        cands = tune.shard_candidates("gemm", n_devices=8)
        tps = [c.as_dict()["tp"] for c in cands]
        assert tps == [1, 2, 4, 8]          # candidate 0 = unsharded
        cands6 = tune.shard_candidates("gemm", n_devices=6)
        assert [c.as_dict()["tp"] for c in cands6] == [1, 2, 3, 6]

    def test_enumerate_dispatch(self):
        from repro.core import tune

        cands = tune.enumerate_candidates("shard:gemm", (64, 256, 128))
        assert cands[0].as_dict()["tp"] == 1

    def test_prune_keeps_default_and_drops_latency_bound(self):
        from repro.core import tune

        cands = tune.shard_candidates("gemm", n_devices=8)
        # tiny decode matmul: every sharded candidate is latency-bound
        kept = tune.prune_shard((8, 128, 64), cands, dtype="bf16")
        tps = [c.as_dict()["tp"] for c, _ in kept]
        assert tps == [1]
        # big matmul: sharding beats the single-chip bound
        kept_big = tune.prune_shard((4096, 4096, 4096), cands,
                                    dtype="bf16")
        assert [c.as_dict()["tp"] for c, _ in kept_big][0] == 1
        assert len(kept_big) > 1

    def test_tuned_shard_roundtrip(self):
        from repro.core import tune

        dims = (64, 256, 128)
        assert tune.tuned_shard("gemm", dims, "bf16") is None
        tune.record_shard_measurement("gemm", dims, "bf16", tp_best=4,
                                      wire_bytes=1234.0)
        assert tune.tuned_shard("gemm", dims, "bf16") == 4
        # veto round-trip: {"tp": 1} records "sharding measured slower"
        tune.record_shard_measurement("gemm", dims, "bf16", tp_best=1)
        assert tune.tuned_shard("gemm", dims, "bf16") == 1

    def test_persistent_roundtrip_across_cache_objects(self):
        from repro.core import tune
        from repro.core.tune.cache import TuningCache, default_cache_dir

        dims = (32, 512, 256)
        tune.record_shard_measurement("persist", dims, "bf16", tp_best=2)
        fresh = TuningCache(default_cache_dir())   # re-reads from disk
        rec = fresh.get("shard:persist", dims, "bf16")
        assert rec is not None and rec.best["tp"] == 2

    def test_shard_report(self):
        from repro.core import tune

        rep = tune.shard_report("gemm", (4096, 4096, 4096), "bf16", tp=4)
        assert rep["strategy"] in ("column", "gather_w")
        assert rep["wire_bytes"] > 0
        assert rep["verdict"] in ("unmeasured", "vetoed", "kept:4",
                                  "kept:2", "kept:8")


# ---------------------------------------------------------------------------
# ShardPlan — the call-site object
# ---------------------------------------------------------------------------

class TestShardPlan:
    def test_plan_wraps_mesh_and_prices_decode(self):
        from repro.configs import get_arch
        from repro.launch.mesh import make_smoke_mesh
        from repro.sharding.plan import ShardPlan

        plan = ShardPlan(make_smoke_mesh())
        cfg = get_arch("qwen2-0.5b").reduced()
        desc = plan.describe()
        assert desc["devices"] == plan.num_devices
        wire = plan.decode_wire_bytes(cfg, batch=2)
        if plan.tp == 1:
            assert wire == 0.0
        else:
            assert wire > 0

    def test_plan_shardings_match_rules(self):
        import jax
        import jax.numpy as jnp
        from repro.launch.mesh import make_smoke_mesh
        from repro.sharding import rules
        from repro.sharding.plan import ShardPlan

        mesh = make_smoke_mesh()
        plan = ShardPlan(mesh)
        tree = {"mlp": {"w_up": jnp.zeros((256, 512))}}
        assert jax.tree.map(
            lambda s: s.spec, plan.params(tree)) == jax.tree.map(
            lambda s: s.spec, rules.params_shardings(tree, mesh))

    def test_smoke_mesh_uses_all_devices(self):
        import jax
        from repro.launch.mesh import make_smoke_mesh

        mesh = make_smoke_mesh()
        assert mesh.devices.size == len(jax.devices())
        assert set(mesh.axis_names) == {"data", "model"}


# ---------------------------------------------------------------------------
# Serve engine resolution (single-device side)
# ---------------------------------------------------------------------------

class TestEngineResolution:
    def test_config_request_clamps_without_devices(self):
        import dataclasses
        import jax

        from repro.configs import get_arch
        from repro.models.model import build_model
        from repro.serve.engine import resolve_tuned_decode_cfg

        cfg = dataclasses.replace(get_arch("qwen2-0.5b").reduced(),
                                  tp_shards=1024)
        model = build_model(cfg)
        tuned, overrides = resolve_tuned_decode_cfg(model, 64)
        assert len(jax.devices()) < 1024
        assert tuned.tp_shards == 1 and overrides["tp_shards"] == 1

    def test_explicit_request_raises_without_devices(self):
        import jax
        import pytest as _pytest

        from repro.configs import get_arch
        from repro.models.model import build_model
        from repro.serve.engine import resolve_tuned_decode_cfg

        model = build_model(get_arch("qwen2-0.5b").reduced())
        with _pytest.raises(ValueError, match="device"):
            resolve_tuned_decode_cfg(model, 64,
                                     tp_shards=len(jax.devices()) + 1)

    def test_measured_veto_turns_sharding_off(self):
        import dataclasses

        from repro.configs import get_arch
        from repro.core import tune
        from repro.models.model import build_model
        from repro.serve.engine import resolve_tuned_decode_cfg

        cfg = dataclasses.replace(get_arch("qwen2-0.5b").reduced(),
                                  tp_shards=2)
        tune.record_shard_measurement(
            "decode_block", (cfg.d_model, cfg.d_ff), "bf16", tp_best=1)
        model = build_model(cfg)
        tuned, overrides = resolve_tuned_decode_cfg(model, 64)
        assert tuned.tp_shards == 1


# ---------------------------------------------------------------------------
# Multi-device execution (subprocess: forced host devices)
# ---------------------------------------------------------------------------

SCRIPT_KERNELS = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.kernels import collective, ops, quant, ref

assert len(jax.devices()) == 4
rng = np.random.default_rng(0)
a = jnp.asarray(rng.standard_normal((16, 64)), jnp.float32)
b = jnp.asarray(rng.standard_normal((64, 128)), jnp.float32)
tile = (8, 128, 128)
want = np.asarray(ops.gemm(a, b, tile=tile, out_dtype=jnp.float32))

# full-output strategies are bitwise vs the unsharded Pallas kernel
for strat in (None, "column", "gather_w"):
    out = np.asarray(ops.tp_gemm(a, b, tp=4, strategy=strat, tile=tile,
                                 out_dtype=jnp.float32))
    assert (out == want).all(), f"tp_gemm {strat} not bitwise"

# all-gather -> GEMM (A row-sharded) and GEMM -> reduce-scatter
out_ag = np.asarray(collective.all_gather_gemm(a, b, tp=4, tile=tile,
                                               out_dtype=jnp.float32))
assert (out_ag == want).all()
out_rs = np.asarray(collective.gemm_reduce_scatter(
    a, b, tp=4, tile=tile, out_dtype=jnp.float32))
want_rs = np.asarray(ref.gemm_reduce_scatter_ref(a, b, tp=4,
                                                 out_dtype=jnp.float32))
assert np.allclose(out_rs, want, atol=1e-4)
assert np.allclose(out_rs, want_rs, atol=1e-5)

# quantized TP: int8 bytes on the wire, bitwise vs unsharded gemm_q
qt = quant.quantize(b, "int8")
want_q = np.asarray(ops.gemm_q(a, qt, tile=tile, out_dtype=jnp.float32))
for strat in (None, "column", "gather_w"):
    out_q = np.asarray(ops.tp_gemm_q(a, qt, tp=4, strategy=strat,
                                     tile=tile, out_dtype=jnp.float32))
    assert (out_q == want_q).all(), f"tp_gemm_q {strat} not bitwise"

# epilogue + col_vector aux shard with the output
bias = jnp.asarray(rng.standard_normal((128,)), jnp.float32)
ep = lambda x, bb: x + bb
want_ep = np.asarray(ref.gemm_ref(a, b, bias, epilogue=ep,
                                  aux_kinds=("col_vector",),
                                  out_dtype=jnp.float32))
out_ep = np.asarray(ops.tp_gemm(a, b, bias, tp=4, strategy="column",
                                tile=tile, epilogue=ep,
                                aux_kinds=("col_vector",),
                                out_dtype=jnp.float32))
assert np.allclose(out_ep, want_ep, atol=1e-5)
print("KERNELS_OK")
"""


SCRIPT_DSL = r"""
import jax, numpy as np, jax.numpy as jnp
from repro.core.dsl.compiler import compile_dsl

SRC = ("gemm().with_dtype(input=fp32, acc=fp32, output=fp32)"
       ".with_sharding(tp=2).with_tile(m=64, n=128, k=128)")
BASE = ("gemm().with_dtype(input=fp32, acc=fp32, output=fp32)"
        ".with_tile(m=64, n=128, k=128)")
rng = np.random.default_rng(0)
a = jnp.asarray(rng.standard_normal((32, 128)), jnp.float32)
b = jnp.asarray(rng.standard_normal((128, 256)), jnp.float32)
for backend in ("pallas", "xla"):
    ck = compile_dsl(SRC, backend,
                     shape_hints={"a": (32, 128), "b": (128, 256)})
    base = compile_dsl(BASE, backend)
    out, want = np.asarray(ck(a, b)), np.asarray(base(a, b))
    assert (out == want).all(), f"{backend}: sharded != unsharded oracle"
    d = ck.sharding.decisions[0]
    assert d.wire_bytes > 0 and d.t_collective is not None

# N not divisible by tp (K is): the SOL plan falls back to the weight-
# gather strategy on BOTH backends (backend-parity regression test)
b_odd = jnp.asarray(rng.standard_normal((128, 130)), jnp.float32)
for backend in ("pallas", "xla"):
    ck = compile_dsl(SRC, backend)
    base = compile_dsl(BASE, backend)
    out, want = np.asarray(ck(a, b_odd)), np.asarray(base(a, b_odd))
    assert (out == want).all(), f"{backend}: gather_w fallback diverged"

# the XLA gather moves the weight at its STORAGE dtype: an int8 gather_w
# program's compiled module must all-gather 1 B/elem, not widened fp32
from repro.core.sol.hlo_analysis import parse_collective_bytes
SRC_Q = ("gemm().with_dtype(input=bf16, acc=fp32, output=bf16)"
         ".with_wdtype(int8).with_sharding(tp=2)")
ck_q = compile_dsl(SRC_Q, "xla",
                   shape_hints={"a": (256, 128), "b": (128, 256)})
aq = jnp.asarray(rng.standard_normal((256, 128)), jnp.float32)
bq = jnp.asarray(rng.standard_normal((128, 256)), jnp.float32)
stats = parse_collective_bytes(
    jax.jit(ck_q.fn).lower(aq, bq).compile().as_text())
shard_int8 = 128 * 256 // 2            # K*N/tp at 1 B/elem
assert stats.bytes_by_opcode.get("all-gather") == shard_int8, \
    stats.as_dict()
print("DSL_OK")
"""


SCRIPT_ENGINE = r"""
import dataclasses
import jax, numpy as np

from repro.configs import get_arch
from repro.models.model import build_model
from repro.serve.engine import Request, ServeEngine

assert len(jax.devices()) == 2
cfg = get_arch("qwen2-0.5b").reduced()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
prompts = [list(map(int, np.random.default_rng(i).integers(
    0, cfg.vocab_size, 6))) for i in range(3)]

def run(tp):
    m = build_model(dataclasses.replace(cfg, tp_shards=tp))
    eng = ServeEngine(m, params, max_batch=2, max_len=32, tp_shards=tp)
    reqs = [Request(rid=i, prompt=list(p), max_new_tokens=4)
            for i, p in enumerate(prompts)]
    eng.run(reqs)
    return eng, [r.out_tokens for r in reqs]

eng1, toks1 = run(1)
eng2, toks2 = run(2)
assert toks1 == toks2, (toks1, toks2)
assert eng1.metrics["wire_bytes_per_step"] == 0
assert eng2.metrics["wire_bytes_per_step"] > 0
assert eng2.shard_plan is not None and eng2.shard_plan.tp == 2
s = eng2.telemetry.summary()
assert s["wire_bytes_per_step"] == eng2.metrics["wire_bytes_per_step"]
print("ENGINE_OK", eng2.metrics["wire_bytes_per_step"])
"""


SCRIPT_SMOKE_MESH = r"""
import jax
from repro.launch.mesh import make_smoke_mesh, make_tp_mesh

assert len(jax.devices()) == 8, len(jax.devices())
mesh = make_smoke_mesh()
assert mesh.devices.size == 8, dict(mesh.shape)
assert dict(mesh.shape) == {"data": 2, "model": 4}
tp = make_tp_mesh(4)
assert dict(tp.shape) == {"data": 1, "model": 4}
print("MESH_OK")
"""


def test_collective_kernels_subprocess():
    assert "KERNELS_OK" in _run_forced(SCRIPT_KERNELS, 4)


def test_dsl_sharding_runs_subprocess():
    assert "DSL_OK" in _run_forced(SCRIPT_DSL, 2)


def test_engine_tp_decode_subprocess():
    assert "ENGINE_OK" in _run_forced(SCRIPT_ENGINE, 2)


def test_smoke_mesh_honors_forced_device_count():
    assert "MESH_OK" in _run_forced(SCRIPT_SMOKE_MESH, 8)
