"""SOL-planned weight quantization: kernels, DSL lever, tune axis, serve.

Covers the quantize->dequantize round-trip error bounds, per-channel vs
per-tensor scale granularity, the dequant-fused Pallas kernels against
their jnp oracles, the DSL ``wdtype`` lever (validation + both backends +
fusion composition), quantization as a tunable axis (budgets, vetoes,
engine resolution), and bitwise determinism of the quantized decode step
across two engine runs.
"""

import dataclasses

import numpy as np
import pytest

jnp = pytest.importorskip("jax.numpy")
import jax  # noqa: E402

from repro.core.dsl import compile_dsl  # noqa: E402
from repro.core.dsl.compiler import validate_dsl  # noqa: E402
from repro.kernels import ops, quant, ref  # noqa: E402

RNG = np.random.default_rng(11)


def _arr(*shape):
    return RNG.standard_normal(shape).astype(np.float32)


class TestQuantizeRoundTrip:
    @pytest.mark.parametrize("wdtype,tol", [("int8", 0.01),
                                            ("fp8_e4m3", 0.08)])
    def test_round_trip_error_bound(self, wdtype, tol):
        w = _arr(256, 128)
        qt = quant.quantize(jnp.asarray(w), wdtype)
        dq = np.asarray(quant.dequantize(qt))
        # per element: |err| <= scale/2 (int8 rounding) resp. fp8 ulp
        rel = np.linalg.norm(dq - w) / np.linalg.norm(w)
        assert rel < tol
        scales = np.asarray(qt.scales)
        if wdtype == "int8":
            assert np.all(np.abs(dq - w) <= scales[None, :] * 0.5 + 1e-7)

    def test_int8_symmetric_grid(self):
        w = _arr(64, 32)
        qt = quant.quantize(jnp.asarray(w), "int8")
        vals = np.asarray(qt.values)
        assert vals.dtype == np.int8
        assert vals.min() >= -127 and vals.max() <= 127

    def test_per_channel_beats_per_tensor_on_outlier_channel(self):
        w = _arr(128, 16)
        w[:, 3] *= 100.0                # one huge output channel
        pc = quant.quantize(jnp.asarray(w), "int8", per_channel=True)
        pt = quant.quantize(jnp.asarray(w), "int8", per_channel=False)
        assert pc.scales.shape == (16,)
        assert pt.scales.shape == ()
        keep = [c for c in range(16) if c != 3]   # the healthy channels
        err_pc = np.linalg.norm(
            (np.asarray(quant.dequantize(pc)) - w)[:, keep])
        err_pt = np.linalg.norm(
            (np.asarray(quant.dequantize(pt)) - w)[:, keep])
        # the outlier inflates every OTHER channel's grid under per-tensor;
        # per-channel scales isolate it
        assert err_pc < err_pt / 10

    def test_batched_scales_shape(self):
        w = _arr(4, 64, 32)
        qt = quant.quantize(jnp.asarray(w), "int8")
        assert qt.scales.shape == (4, 32)     # per (group, channel)

    def test_quant_tensor_is_pytree(self):
        qt = quant.quantize(jnp.asarray(_arr(8, 16)), "int8")
        leaves = jax.tree.leaves(qt)
        assert len(leaves) == 2
        rebuilt = jax.tree.map(lambda x: x, qt)
        assert isinstance(rebuilt, quant.QuantTensor)
        assert rebuilt.wdtype == "int8"

    def test_unknown_wdtype_rejected(self):
        with pytest.raises(KeyError):
            quant.quantize(jnp.asarray(_arr(8, 16)), "int4")

    def test_quantize_cached_memoizes_per_buffer(self):
        w = jnp.asarray(_arr(64, 32))
        q1 = quant.quantize_cached(w, "int8")
        q2 = quant.quantize_cached(w, "int8")
        assert q1 is q2                       # one quantization per buffer
        q3 = quant.quantize_cached(w, "int8", per_channel=False)
        assert q3 is not q1                   # granularity keys apart
        w2 = jnp.asarray(_arr(64, 32))
        assert quant.quantize_cached(w2, "int8") is not q1


class TestQuantKernelsVsOracles:
    @pytest.mark.parametrize("wdtype", ["int8", "fp8_e4m3"])
    def test_gemm_q_matches_ref(self, wdtype):
        a, w = _arr(40, 96), _arr(96, 112)
        qt = quant.quantize(jnp.asarray(w), wdtype)
        out = np.asarray(ops.gemm_q(jnp.asarray(a), qt, tile=(64, 128, 128),
                                    out_dtype=jnp.float32))
        want = np.asarray(ref.gemm_q_ref(jnp.asarray(a), qt.values,
                                         qt.scales, out_dtype=jnp.float32))
        np.testing.assert_allclose(out, want, rtol=2e-4, atol=2e-4)

    def test_gemm_q_epilogue_after_scales(self):
        a, w, bias = _arr(32, 64), _arr(64, 128), _arr(128)
        qt = quant.quantize(jnp.asarray(w), "int8")
        ep = lambda x, b: x + b  # noqa: E731
        out = np.asarray(ops.gemm_q(
            jnp.asarray(a), qt, None, jnp.asarray(bias),
            tile=(64, 128, 128), epilogue=ep, aux_kinds=("col_vector",),
            out_dtype=jnp.float32))
        want = np.asarray(ref.gemm_q_ref(
            jnp.asarray(a), qt.values, qt.scales, jnp.asarray(bias),
            epilogue=ep, aux_kinds=("col_vector",), out_dtype=jnp.float32))
        np.testing.assert_allclose(out, want, rtol=2e-4, atol=2e-4)

    def test_batched_gemm_q_matches_ref(self):
        a, w = _arr(3, 24, 64), _arr(3, 64, 128)
        qt = quant.quantize(jnp.asarray(w), "int8")
        out = np.asarray(ops.batched_gemm_q(
            jnp.asarray(a), qt, tile=(64, 128, 128),
            out_dtype=jnp.float32))
        want = np.asarray(ref.batched_gemm_q_ref(
            jnp.asarray(a), qt.values, qt.scales, out_dtype=jnp.float32))
        np.testing.assert_allclose(out, want, rtol=2e-4, atol=2e-4)

    def test_rmsnorm_gemm_q_matches_ref(self):
        x, g, w = _arr(40, 192), _arr(192), _arr(192, 96)
        qt = quant.quantize(jnp.asarray(w), "int8")
        out = np.asarray(ops.rmsnorm_gemm_q(
            jnp.asarray(x), jnp.asarray(g), qt, tile=(64, 128, 128),
            out_dtype=jnp.float32))
        want = np.asarray(ref.rmsnorm_gemm_q_ref(
            jnp.asarray(x), jnp.asarray(g), qt.values, qt.scales,
            out_dtype=jnp.float32))
        np.testing.assert_allclose(out, want, rtol=2e-4, atol=2e-4)

    def test_per_tensor_scales_accepted(self):
        a, w = _arr(16, 64), _arr(64, 128)
        qt = quant.quantize(jnp.asarray(w), "int8", per_channel=False)
        out = np.asarray(ops.gemm_q(jnp.asarray(a), qt, tile=(64, 128, 128),
                                    out_dtype=jnp.float32))
        want = np.asarray(ref.gemm_q_ref(jnp.asarray(a), qt.values,
                                         qt.scales, out_dtype=jnp.float32))
        np.testing.assert_allclose(out, want, rtol=2e-4, atol=2e-4)

    def test_sub_tile_k_clamp_shared_by_fp_and_quant(self):
        """K=64 under the library's default bk: both paths clamp through
        the shared helper and still match their oracles."""
        a, w = _arr(16, 64), _arr(64, 128)
        assert ops.clamp_tile((256, 256, 512), 16, 128, 64,
                              np.float32) == (16, 128, 128)
        out_fp = np.asarray(ops.gemm(jnp.asarray(a), jnp.asarray(w),
                                     out_dtype=jnp.float32))
        np.testing.assert_allclose(out_fp, a @ w, rtol=2e-4, atol=2e-4)
        qt = quant.quantize(jnp.asarray(w), "int8")
        out_q = np.asarray(ops.gemm_q(jnp.asarray(a), qt,
                                      out_dtype=jnp.float32))
        want = np.asarray(ref.gemm_q_ref(jnp.asarray(a), qt.values,
                                         qt.scales, out_dtype=jnp.float32))
        np.testing.assert_allclose(out_q, want, rtol=2e-4, atol=2e-4)

    def test_clamp_respects_sublane_packing(self):
        assert ops.clamp_tile((256, 256, 512), 20, 100, 60,
                              jnp.bfloat16)[0] == 32   # bf16 sublane 16
        assert ops.clamp_tile((256, 256, 512), 20, 100, 60,
                              np.float32) == (24, 128, 128)


WDTYPE_GEMM = ("gemm().with_dtype(input=fp32, acc=fp32, output=fp32)"
               ".with_wdtype(int8).with_tile(m=64, n=128, k=128)")


class TestDSLWdtypeLever:
    def test_wdtype_in_canonical_namespace(self):
        k = compile_dsl(WDTYPE_GEMM, "pallas", use_cache=False)
        kf = compile_dsl(WDTYPE_GEMM.replace(".with_wdtype(int8)", ""),
                         "pallas", use_cache=False)
        assert k.namespace != kf.namespace
        assert k.ir.wdtype == "int8" and k.ir.wscale == "per_channel"

    @pytest.mark.parametrize("backend", ["pallas", "xla"])
    def test_backends_agree(self, backend):
        a, w, bias = _arr(32, 96), _arr(96, 112), _arr(112)
        src = WDTYPE_GEMM + " >> bias()"
        k = compile_dsl(src, backend, use_cache=False)
        out = np.asarray(k(a, w, bias))
        qt = quant.quantize(jnp.asarray(w), "int8")
        want = np.asarray(ref.gemm_q_ref(
            jnp.asarray(a), qt.values, qt.scales, jnp.asarray(bias),
            epilogue=lambda x, b: x + b, aux_kinds=("col_vector",),
            out_dtype=jnp.float32))
        np.testing.assert_allclose(out, want, rtol=2e-4, atol=2e-4)

    def test_dimension_semantics_threads_through_quantized_route(self):
        src = ("gemm().with_dtype(input=fp32, acc=fp32, output=fp32)"
               ".with_wdtype(int8).with_tile(m=64, n=128, k=128)"
               ".with_dimension_semantics(arbitrary, arbitrary, arbitrary)")
        k = compile_dsl(src, "pallas", use_cache=False)
        assert "dimension_semantics=('arbitrary'" in k.source
        a, w = _arr(16, 64), _arr(64, 128)
        qt = quant.quantize(jnp.asarray(w), "int8")
        want = np.asarray(ref.gemm_q_ref(jnp.asarray(a), qt.values,
                                         qt.scales, out_dtype=jnp.float32))
        np.testing.assert_allclose(np.asarray(k(a, w)), want,
                                   rtol=2e-4, atol=2e-4)

    def test_per_tensor_scale_param(self):
        src = ("gemm().with_dtype(input=fp32, acc=fp32, output=fp32)"
               ".with_wdtype(int8, scale=per_tensor)"
               ".with_tile(m=64, n=128, k=128)")
        k = compile_dsl(src, "pallas", use_cache=False)
        assert k.ir.wscale == "per_tensor"
        a, w = _arr(16, 64), _arr(64, 128)
        qt = quant.quantize(jnp.asarray(w), "int8", per_channel=False)
        want = np.asarray(ref.gemm_q_ref(jnp.asarray(a), qt.values,
                                         qt.scales, out_dtype=jnp.float32))
        np.testing.assert_allclose(np.asarray(k(a, w)), want,
                                   rtol=2e-4, atol=2e-4)

    def test_batched_gemm_wdtype(self):
        src = ("batched_gemm().with_dtype(input=fp32, acc=fp32,"
               " output=fp32).with_wdtype(int8)"
               ".with_tile(m=64, n=128, k=128)")
        k = compile_dsl(src, "pallas", use_cache=False)
        a, w = _arr(2, 24, 64), _arr(2, 64, 128)
        qt = quant.quantize(jnp.asarray(w), "int8")
        want = np.asarray(ref.batched_gemm_q_ref(
            jnp.asarray(a), qt.values, qt.scales, out_dtype=jnp.float32))
        np.testing.assert_allclose(np.asarray(k(a, w)), want,
                                   rtol=2e-4, atol=2e-4)

    # ---- validation ------------------------------------------------------
    def test_fp8_wdtype_arch_gated(self):
        errs = validate_dsl("gemm().with_dtype(input=bf16, acc=fp32,"
                            " output=bf16).with_wdtype(fp8_e4m3)")
        assert [e.code for e in errs] == ["E_WDTYPE_ARCH"]
        errs = validate_dsl("gemm().with_dtype(input=bf16, acc=fp32,"
                            " output=bf16).with_arch(tpu_v5p)"
                            ".with_wdtype(fp8_e4m3)")
        assert errs == []

    def test_wide_wdtype_rejected(self):
        errs = validate_dsl("gemm().with_dtype(input=fp32, acc=fp32,"
                            " output=fp32).with_wdtype(bf16)")
        assert "E_WDTYPE" in [e.code for e in errs]

    def test_wdtype_requires_fp32_acc(self):
        errs = validate_dsl("gemm().with_dtype(input=int8, acc=int32,"
                            " output=int8).with_wdtype(int8)")
        assert "E_WDTYPE_ACC" in [e.code for e in errs]

    def test_wdtype_swap_rejected(self):
        errs = validate_dsl("gemm().with_dtype(input=fp32, acc=fp32,"
                            " output=fp32).with_wdtype(int8)"
                            ".with_swap(true)")
        assert "E_WDTYPE_SWAP" in [e.code for e in errs]

    def test_wdtype_rowstat_epilogue_rejected(self):
        errs = validate_dsl(WDTYPE_GEMM + " >> rmsnorm()")
        assert "E_WDTYPE_ROWSTAT" in [e.code for e in errs]

    def test_wdtype_family_gated(self):
        errs = validate_dsl("rmsnorm().with_dtype(input=fp32, acc=fp32,"
                            " output=fp32).with_wdtype(int8)")
        assert "E_CFG_FAMILY" in [e.code for e in errs]


class TestQuantFusionComposition:
    SRC = ("pipeline(rmsnorm().with_dtype(input=fp32, acc=fp32,"
           " output=fp32), " + WDTYPE_GEMM + " >> bias())")

    def _arrays(self):
        return dict(x=_arr(48, 256), gamma=_arr(256), b_s1=_arr(256, 128),
                    bias_s1=_arr(128))

    def test_rmsnorm_gemm_q_fuses_bitwise(self):
        arrays = self._arrays()
        hints = {n: a.shape for n, a in arrays.items()}
        kf = compile_dsl(self.SRC, "pallas", use_cache=False, fuse="auto",
                         shape_hints=hints)
        ku = compile_dsl(self.SRC, "pallas", use_cache=False, fuse="off")
        assert len(kf.ir.kernel_stages) == 1
        assert kf.ir.kernel_stages[0].op_name == "rmsnorm_gemm"
        assert kf.ir.kernel_stages[0].wdtype == "int8"
        amap = dict(arrays)
        amap.update(b=arrays["b_s1"], bias=arrays["bias_s1"])
        out_f = np.asarray(kf.bind(**amap))
        out_u = np.asarray(ku.bind(**amap))
        np.testing.assert_array_equal(out_f, out_u)

    def test_xla_backend_fused_agrees(self):
        arrays = self._arrays()
        hints = {n: a.shape for n, a in arrays.items()}
        kf = compile_dsl(self.SRC, "xla", use_cache=False, fuse="auto",
                         shape_hints=hints)
        ku = compile_dsl(self.SRC, "xla", use_cache=False, fuse="off")
        amap = dict(arrays)
        amap.update(b=arrays["b_s1"], bias=arrays["bias_s1"])
        np.testing.assert_array_equal(np.asarray(kf.bind(**amap)),
                                      np.asarray(ku.bind(**amap)))

    def test_gemm_gemm_declines_quantized_stage(self):
        src = ("pipeline(" + WDTYPE_GEMM + ", "
               "gemm().with_dtype(input=fp32, acc=fp32, output=fp32)"
               ".with_tile(m=64, n=128, k=128))")
        k = compile_dsl(src, "pallas", use_cache=False, fuse="force")
        assert len(k.ir.kernel_stages) == 2
        assert "quantized" in k.fusion.decisions[0].reason

    def test_fold_rmsnorm_declines_quantized_producer(self):
        src = ("pipeline(" + WDTYPE_GEMM + ", "
               "rmsnorm().with_dtype(input=fp32, acc=fp32, output=fp32))")
        k = compile_dsl(src, "pallas", use_cache=False, fuse="force")
        assert len(k.ir.kernel_stages) == 2
        assert "quantized" in k.fusion.decisions[0].reason

    def test_fold_eltwise_onto_quantized_producer(self):
        src = ("pipeline(" + WDTYPE_GEMM + ", "
               "eltwise().with_dtype(input=fp32, acc=fp32, output=fp32)"
               " >> gelu())")
        arrays = dict(a=_arr(32, 128), b=_arr(128, 128))
        hints = {n: a.shape for n, a in arrays.items()}
        kf = compile_dsl(src, "pallas", use_cache=False, fuse="auto",
                         shape_hints=hints)
        ku = compile_dsl(src, "pallas", use_cache=False, fuse="off")
        assert len(kf.ir.kernel_stages) == 1
        assert kf.ir.kernel_stages[0].wdtype == "int8"
        np.testing.assert_array_equal(
            np.asarray(kf.bind(**arrays)), np.asarray(ku.bind(**arrays)))


class TestQuantTuneAxis:
    def test_candidates_default_first(self):
        cands = __import__("repro.core.tune", fromlist=["tune"]) \
            .quant_candidates("gemm")
        assert cands[0].as_dict() == {"wdtype": "none"}
        assert {c.as_dict()["wdtype"] for c in cands[1:]} \
            == {"int8", "fp8_e4m3"}

    def test_prune_quant_keeps_weight_heavy_drops_nothing_saved(self):
        from repro.core import tune
        cands = tune.quant_candidates("gemm")
        # decode shape: weights dominate -> quant candidates survive
        kept = tune.prune_quant((8, 512, 256), cands, dtype="fp32")
        assert len(kept) == len(cands)
        # giant activation, tiny weight: nothing meaningful to save
        kept = tune.prune_quant((65536, 8, 8), cands, dtype="fp32",
                                min_saved_frac=0.05)
        assert [c.as_dict()["wdtype"] for c, _ in kept] == ["none"]

    def test_record_and_veto_roundtrip(self, tmp_path, monkeypatch):
        monkeypatch.setenv("REPRO_TUNE_DIR", str(tmp_path))
        monkeypatch.delenv("REPRO_TUNE_DISABLE", raising=False)
        from repro.core import tune
        dims = (64, 128, 64)
        assert tune.tuned_wdtype("gemm", dims, "fp32") is None
        tune.record_quant_measurement("gemm", dims, "fp32",
                                      wdtype_best="int8", rel_err=0.003,
                                      budget=0.02)
        assert tune.tuned_wdtype("gemm", dims, "fp32") == "int8"
        tune.record_quant_measurement("gemm", dims, "fp32",
                                      wdtype_best="none", rel_err=0.5,
                                      budget=0.02)
        assert tune.tuned_wdtype("gemm", dims, "fp32") == "none"

    def test_repro_quant_off_silences_lookups(self, tmp_path, monkeypatch):
        monkeypatch.setenv("REPRO_TUNE_DIR", str(tmp_path))
        monkeypatch.delenv("REPRO_TUNE_DISABLE", raising=False)
        from repro.core import tune
        tune.record_quant_measurement("gemm", (8, 8, 8), "fp32",
                                      wdtype_best="int8")
        monkeypatch.setenv("REPRO_QUANT", "off")
        assert tune.tuned_wdtype("gemm", (8, 8, 8), "fp32") is None

    def test_budgets_and_env_override(self, monkeypatch):
        from repro.core import tune
        assert tune.quant_error_budget("int8") == 0.02
        assert tune.quant_error_budget("fp8_e4m3") > \
            tune.quant_error_budget("int8")
        monkeypatch.setenv("REPRO_QUANT_BUDGET", "0.5")
        assert tune.quant_error_budget("int8") == 0.5

    def test_cite_quant_report(self, tmp_path, monkeypatch):
        monkeypatch.setenv("REPRO_TUNE_DIR", str(tmp_path))
        monkeypatch.delenv("REPRO_TUNE_DISABLE", raising=False)
        from repro.core import tune
        from repro.core.agent.costmodel import cite_quant_report
        dims = (8, 512, 256)
        line = cite_quant_report(tune.quant_report("gemm", dims, "bf16"))
        assert "int8 weights save" in line and "unmeasured" in line
        tune.record_quant_measurement("gemm", dims, "bf16",
                                      wdtype_best="none", rel_err=0.9,
                                      budget=0.02)
        line = cite_quant_report(tune.quant_report("gemm", dims, "bf16"))
        assert "VETOED" in line
        assert cite_quant_report(None).startswith("no quantization")

    def test_dtype_aware_roofline(self):
        from repro.core.sol.roofline import (matmul_hbm_bytes,
                                             matmul_roofline,
                                             quant_bytes_saved)
        fp = matmul_hbm_bytes(8, 256, 512, a_dtype="fp32", w_dtype="fp32")
        q8 = matmul_hbm_bytes(8, 256, 512, a_dtype="fp32", w_dtype="int8")
        # weight term shrinks 4x (+ scales); activations/output unchanged
        assert fp - q8 == 512 * 256 * 3 - 256 * 4
        saved, frac = quant_bytes_saved(8, 256, 512, w_dtype_from="fp32",
                                        w_dtype_to="int8", a_dtype="fp32")
        assert saved == fp - q8 and 0 < frac < 1
        r = matmul_roofline(8, 256, 512, a_dtype="bf16", w_dtype="int8")
        assert r.bottleneck == "memory"      # decode shape is memory-bound
        assert r.hbm_bytes == matmul_hbm_bytes(8, 256, 512, a_dtype="bf16",
                                               w_dtype="int8")


class TestServeQuantizedDecode:
    def _build(self, weight_dtype="int8"):
        from repro.configs import get_arch
        from repro.models.model import build_model
        cfg = dataclasses.replace(get_arch("qwen2-0.5b").reduced(),
                                  tie_embeddings=False,
                                  weight_dtype=weight_dtype)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        return model, params

    def test_quantize_params_targets_projections_only(self):
        model, params = self._build()
        qp = model.quantize_params(params)
        assert isinstance(qp["layers"]["attn"]["wq"], quant.QuantTensor)
        assert isinstance(qp["layers"]["mlp"]["w_down"], quant.QuantTensor)
        assert isinstance(qp["lm_head"], quant.QuantTensor)
        assert not isinstance(qp["embed"], quant.QuantTensor)
        assert not isinstance(qp["layers"]["norm1"]["gamma"],
                              quant.QuantTensor)
        assert model.num_quantized_matmuls(qp) \
            == model.cfg.num_layers * 7 + 1   # swiglu: 4 attn + 3 mlp

    def test_weight_bytes_drop_at_least_3x(self):
        model, params = self._build()
        qp = model.quantize_params(params)
        fp_bytes = model.decode_weight_bytes(params)
        q_bytes = model.decode_weight_bytes(qp)
        assert fp_bytes / q_bytes >= 3.0

    def test_quantized_prefill_within_model_budget(self):
        from repro.core import tune
        model, params = self._build()
        qp = model.quantize_params(params)
        toks = jnp.asarray([[3, 5, 7, 2], [11, 2, 4, 9]], jnp.int32)
        counts = jnp.asarray([4, 4], jnp.int32)
        lf, _ = model.prefill_step(params, model.init_cache(2, 16), toks,
                                   counts)
        lq, _ = model.prefill_step(qp, model.init_cache(2, 16), toks,
                                   counts)
        lf = np.asarray(lf, np.float32)
        lq = np.asarray(lq, np.float32)
        rel = np.linalg.norm(lq - lf) / np.linalg.norm(lf)
        budget = tune.model_error_budget(
            "int8", model.num_quantized_matmuls(qp))
        assert rel <= budget

    def test_engine_decode_bitwise_deterministic_across_runs(self):
        from repro.serve import Request, ServeEngine
        model, params = self._build()

        def run():
            eng = ServeEngine(model, params, max_batch=2, max_len=48,
                              chunk_size=8, weight_dtype="int8", seed=3)
            reqs = [Request(rid=i, prompt=[3 + i, 5, 7, 2, 9],
                            max_new_tokens=5, temperature=0.8)
                    for i in range(3)]
            eng.run(reqs)
            return eng, [r.out_tokens for r in reqs]

        eng_a, out_a = run()
        eng_b, out_b = run()
        assert eng_a.model.cfg.weight_dtype == "int8"
        assert out_a == out_b                 # bitwise-deterministic decode
        assert eng_a.weight_bytes_per_step == eng_b.weight_bytes_per_step
        assert eng_a.metrics["weight_bytes_per_step"] \
            == eng_a.weight_bytes_per_step

    def test_repro_quant_off_escape_hatch(self, monkeypatch):
        from repro.serve import ServeEngine
        model, params = self._build()
        monkeypatch.setenv("REPRO_QUANT", "off")
        eng = ServeEngine(model, params, max_batch=2, max_len=32,
                          chunk_size=8)
        assert eng.model.cfg.weight_dtype == "none"
        assert not any(isinstance(leaf, quant.QuantTensor)
                       for leaf in jax.tree.leaves(
                           eng.params,
                           is_leaf=lambda x: isinstance(
                               x, quant.QuantTensor)))

    def test_tuned_veto_flips_engine_off_but_explicit_forces(
            self, tmp_path, monkeypatch):
        monkeypatch.setenv("REPRO_TUNE_DIR", str(tmp_path))
        monkeypatch.delenv("REPRO_TUNE_DISABLE", raising=False)
        from repro.core import tune
        from repro.serve import ServeEngine
        model, params = self._build()
        cfg = model.cfg
        tune.record_quant_measurement(
            "decode_block", (cfg.d_model, cfg.d_ff), cfg.compute_dtype,
            wdtype_best="none", rel_err=0.9, budget=0.001)
        eng = ServeEngine(model, params, max_batch=2, max_len=32,
                          chunk_size=8)
        assert eng.model.cfg.weight_dtype == "none"   # veto honored
        eng = ServeEngine(model, params, max_batch=2, max_len=32,
                          chunk_size=8, weight_dtype="int8")
        assert eng.model.cfg.weight_dtype == "int8"   # explicit forces

    def test_quantized_works_with_fused_decode(self):
        model, params = self._build()
        fused = dataclasses.replace(
            model, cfg=dataclasses.replace(model.cfg, fused_decode=True))
        qp = model.quantize_params(params)
        toks = jnp.asarray([[3, 5, 7, 2]], jnp.int32)
        counts = jnp.asarray([4], jnp.int32)
        la, _ = model.prefill_step(qp, model.init_cache(1, 16), toks,
                                   counts)
        lb, _ = fused.prefill_step(qp, fused.init_cache(1, 16), toks,
                                   counts)
        # the fused decode block preserves bitwise identity even over
        # quantized projections (same primitive order)
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
