"""Observability: tracer, metrics exposition, drift detection, the shared
JSON serializer, serving-telemetry empty-input semantics, and the drift
consumers in the integrity pipeline and agent cost model."""

import dataclasses
import enum
import json
import math

import numpy as np
import pytest

from repro.core.obs import trace as trace_mod
from repro.core.obs.drift import DriftDetector
from repro.core.obs.metrics import (DEFAULT_BUCKETS, MetricsRegistry,
                                    default_registry)
from repro.core.obs.serialize import to_jsonable
from repro.core.obs.trace import (NULL_SPAN, NULL_TRACER, Tracer, configure,
                                  disable, get_tracer)


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------

class TestTracer:
    def test_span_records_name_cat_attrs(self):
        tr = Tracer()
        with tr.span("compile.dsl", cat="compile", backend="xla") as sp:
            sp.set(fused_count=2)
        (s,) = tr.spans()
        assert s.name == "compile.dsl"
        assert s.cat == "compile"
        assert s.ph == "X"
        assert s.dur >= 0
        assert s.attrs == {"backend": "xla", "fused_count": 2}

    def test_event_is_instant(self):
        tr = Tracer()
        tr.event("tune.cache_hit", cat="tune", op="gemm")
        (s,) = tr.spans()
        assert s.ph == "i"
        assert s.dur == 0.0
        assert s.attrs["op"] == "gemm"

    def test_complete_backdates_start(self):
        tr = Tracer()
        tr.complete("tune.trial", dur_s=0.25, cat="tune")
        (s,) = tr.spans()
        assert s.ph == "X"
        assert s.dur == pytest.approx(0.25)
        assert s.ts >= 0.0

    def test_sol_efficiency_computed_on_close(self):
        tr = Tracer()
        tr.complete("engine.step", dur_s=1.0, cat="serve",
                    sol={"t_sol_s": 0.25, "bound": "memory"})
        (s,) = tr.spans()
        assert s.sol_efficiency == pytest.approx(0.25)

    def test_span_exception_sets_error_attr(self):
        tr = Tracer()
        with pytest.raises(RuntimeError):
            with tr.span("compile.dsl", cat="compile"):
                raise RuntimeError("boom")
        (s,) = tr.spans()
        assert s.attrs["error"] == "boom"

    def test_ring_buffer_drops_oldest(self):
        tr = Tracer(ring=4)
        for i in range(10):
            tr.event(f"e{i}")
        spans = tr.spans()
        assert len(spans) == 4
        assert [s.name for s in spans] == ["e6", "e7", "e8", "e9"]
        assert tr.dropped == 6

    def test_drift_fed_from_sol_payload(self):
        drift = DriftDetector()
        tr = Tracer(drift=drift)
        tr.complete("tune.trial", dur_s=0.002, cat="tune",
                    sol={"t_sol_s": 1e-3, "predicted": 1e-3,
                         "measured": 2e-3, "op": "tune.gemm"})
        rep = drift.report()
        assert rep["tune.gemm"]["n"] == 1
        assert rep["tune.gemm"]["mean_ratio"] == pytest.approx(2.0)

    def test_drift_measured_defaults_to_span_duration(self):
        drift = DriftDetector()
        tr = Tracer(drift=drift)
        tr.complete("engine.step", dur_s=0.5, cat="serve",
                    sol={"t_sol_s": 0.1, "predicted": 0.1})
        rep = drift.report()
        assert rep["engine.step"]["mean_ratio"] == pytest.approx(5.0)

    def test_jsonl_sink_streams_spans(self, tmp_path):
        path = str(tmp_path / "trace.jsonl")
        tr = Tracer(jsonl_path=path)
        tr.event("a", cat="compile")
        tr.complete("b", dur_s=0.1, cat="tune")
        tr.close()
        lines = [json.loads(line)
                 for line in open(path).read().splitlines()]
        assert [d["name"] for d in lines] == ["a", "b"]
        assert lines[1]["dur_s"] == pytest.approx(0.1)
        assert lines[0]["ph"] == "i"

    def test_chrome_export_structure(self, tmp_path):
        tr = Tracer()
        tr.event("hit", cat="compile")
        tr.complete("step", dur_s=0.5, cat="serve",
                    sol={"t_sol_s": 0.1, "flops": 1e9})
        path = tr.export_chrome(str(tmp_path / "trace.json"))
        data = json.load(open(path))
        evs = data["traceEvents"]
        assert len(evs) == 2
        instant = next(e for e in evs if e["ph"] == "i")
        span = next(e for e in evs if e["ph"] == "X")
        assert instant["s"] == "t"          # thread-scoped instant
        assert span["dur"] == pytest.approx(0.5e6)   # microseconds
        assert span["args"]["sol"]["flops"] == 1e9
        assert data["otherData"]["dropped_spans"] == 0

    def test_null_tracer_is_noop(self):
        assert NULL_TRACER.enabled is False
        sp = NULL_TRACER.span("x", cat="serve", big_attr="ignored")
        assert sp is NULL_SPAN
        with sp as inner:
            inner.set(anything=1)
        NULL_TRACER.event("x")
        NULL_TRACER.complete("x", dur_s=1.0)
        assert NULL_TRACER.spans() == []
        assert NULL_TRACER.categories() == []
        with pytest.raises(RuntimeError):
            NULL_TRACER.export_chrome("/tmp/nope.json")

    def test_configure_and_disable(self, tmp_path):
        try:
            tr = configure(str(tmp_path / "t.json"), export_at_exit=False)
            assert get_tracer() is tr
            assert tr.enabled
            tr.event("x", cat="compile")
            assert tr.categories() == ["compile"]
        finally:
            disable()
        assert get_tracer() is NULL_TRACER

    def test_repro_trace_env_configures_lazily(self, tmp_path,
                                               monkeypatch):
        path = str(tmp_path / "env.jsonl")
        monkeypatch.setenv("REPRO_TRACE", path)
        monkeypatch.setattr(trace_mod, "_ENV_CHECKED", False)
        try:
            tr = get_tracer()
            assert tr.enabled
            tr.event("from_env")
            tr.flush()
            assert json.loads(open(path).read())["name"] == "from_env"
        finally:
            disable()


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

class TestMetrics:
    def test_counter_inc_and_labels(self):
        reg = MetricsRegistry()
        c = reg.counter("reqs_total", "requests", labels=("slo",))
        c.inc(slo="interactive")
        c.inc(2, slo="batch")
        assert c.value(slo="interactive") == 1
        assert c.value(slo="batch") == 2
        assert c.value(slo="unseen") == 0
        with pytest.raises(ValueError):
            c.inc(-1, slo="batch")
        with pytest.raises(KeyError):
            c.inc(nope="x")

    def test_gauge_set(self):
        reg = MetricsRegistry()
        g = reg.gauge("depth")
        g.set(7.5)
        assert g.value() == 7.5
        g.inc(-2.5)                       # gauges may go down
        assert g.value() == 5.0

    def test_histogram_cumulative_buckets(self):
        reg = MetricsRegistry()
        h = reg.histogram("lat", buckets=(0.1, 1.0))
        for v in (0.05, 0.5, 5.0):
            h.observe(v)
        h.observe(float("nan"))           # ignored, not counted
        assert h.count() == 3
        text = reg.render_prometheus()
        assert 'lat_bucket{le="0.1"} 1' in text
        assert 'lat_bucket{le="1"} 2' in text
        assert 'lat_bucket{le="+Inf"} 3' in text
        assert "lat_sum 5.55" in text
        assert "lat_count 3" in text

    def test_render_prometheus_help_type_and_escaping(self):
        reg = MetricsRegistry()
        reg.counter("c_total", "counts\nthings", labels=("tag",)) \
            .inc(tag='we"ird')
        text = reg.render_prometheus()
        assert "# HELP c_total counts\\nthings" in text
        assert "# TYPE c_total counter" in text
        assert 'c_total{tag="we\\"ird"} 1' in text

    def test_get_or_create_returns_same_instrument(self):
        reg = MetricsRegistry()
        assert reg.counter("x_total") is reg.counter("x_total")

    def test_kind_conflict_raises(self):
        reg = MetricsRegistry()
        reg.counter("x_total")
        with pytest.raises(TypeError):
            reg.gauge("x_total")

    def test_snapshot_json_twin(self):
        reg = MetricsRegistry()
        reg.counter("plain_total").inc(3)
        reg.counter("lab_total", labels=("k",)).inc(k="v")
        reg.histogram("h", buckets=(1.0,)).observe(0.5)
        snap = reg.snapshot()
        assert snap["plain_total"]["values"] == 3.0
        assert snap["lab_total"]["values"] == [
            {"labels": {"k": "v"}, "value": 1.0}]
        assert snap["h"]["values"][0]["count"] == 1.0
        assert snap["h"]["type"] == "histogram"

    def test_default_buckets_end_in_inf(self):
        assert math.isinf(DEFAULT_BUCKETS[-1])
        assert default_registry() is default_registry()


# ---------------------------------------------------------------------------
# drift detection
# ---------------------------------------------------------------------------

class TestDrift:
    def test_below_bound_fires_on_transition_only(self):
        d = DriftDetector(min_samples=3)
        events = [d.observe("op", 1.0, 0.5) for _ in range(6)]
        # min_samples gates the first two; the third transitions; the
        # rest are the SAME incident, so no further events
        assert events[0] is None and events[1] is None
        assert events[2] is not None
        assert events[2].direction == "below_bound"
        assert events[2].n == 3
        assert all(e is None for e in events[3:])
        assert d.drifting_ops() == ["op"]
        assert len(d.events) == 1

    def test_uncalibrated_bound_never_flags_slow_measurement(self):
        # CPU interpret mode: measured >> SOL bound is expected, not drift
        d = DriftDetector()
        for _ in range(20):
            assert d.observe("engine.step", 1e-4, 5.0) is None
        assert d.drifting_ops() == []
        assert d.report()["engine.step"]["drifting"] is False

    def test_calibrated_model_flags_above(self):
        d = DriftDetector(min_samples=3)
        events = [d.observe("op", 1.0, 2.0, calibrated=True)
                  for _ in range(3)]
        assert events[2] is not None
        assert events[2].direction == "above_model"

    def test_recovery_then_refire(self):
        d = DriftDetector(window=4, min_samples=2)
        d.observe("op", 1.0, 0.5)
        ev1 = d.observe("op", 1.0, 0.5)
        assert ev1 is not None
        # window refills with healthy ratios -> drift clears
        for _ in range(4):
            d.observe("op", 1.0, 1.0)
        assert d.drifting_ops() == []
        # a NEW sustained excursion is a new incident
        evs = [d.observe("op", 1.0, 0.5) for _ in range(4)]
        assert any(e is not None for e in evs)
        assert len(d.events) == 2

    def test_invalid_observations_ignored(self):
        d = DriftDetector()
        assert d.observe("op", 0.0, 1.0) is None     # bound must be > 0
        assert d.observe("op", 1.0, -1.0) is None
        assert d.observe("op", None, 1.0) is None
        assert d.report() == {}

    def test_report_and_table(self):
        d = DriftDetector(min_samples=1)
        d.observe("a", 2.0, 1.0, unit="bytes", calibrated=True)
        rep = d.report()["a"]
        assert rep["n"] == 1
        assert rep["mean_ratio"] == pytest.approx(0.5)
        assert rep["drifting"] is True
        assert rep["unit"] == "bytes"
        table = d.table()
        assert "| a | 1 | 0.5 | bytes | yes | below_bound |" in table

    def test_on_event_callback(self):
        seen = []
        d = DriftDetector(min_samples=1, on_event=seen.append)
        d.observe("op", 1.0, 0.1)
        assert len(seen) == 1 and seen[0].op == "op"

    def test_gauge_published_on_every_observe(self):
        d = DriftDetector()
        d.observe("gauge_test_op", 1.0, 1.5)
        g = default_registry().get("repro_sol_drift_ratio")
        assert g is not None
        assert g.value(op="gauge_test_op") == pytest.approx(1.5)


# ---------------------------------------------------------------------------
# shared JSON serializer
# ---------------------------------------------------------------------------

class TestToJsonable:
    def test_nan_and_inf_become_null(self):
        assert to_jsonable(float("nan")) is None
        assert to_jsonable(float("inf")) is None
        assert to_jsonable({"p95": float("nan"), "n": 3}) == \
            {"p95": None, "n": 3}

    def test_numpy_values(self):
        assert to_jsonable(np.float64(1.5)) == 1.5
        assert to_jsonable(np.int32(7)) == 7
        assert to_jsonable(np.array([1, 2])) == [1, 2]
        assert to_jsonable(np.float64("nan")) is None

    def test_dataclass_enum_and_keys(self):
        class Color(enum.Enum):
            RED = "red"

        @dataclasses.dataclass
        class Point:
            x: int
            c: Color

        assert to_jsonable(Point(1, Color.RED)) == {"x": 1, "c": "red"}
        assert to_jsonable({3: "v"}) == {"3": "v"}

    def test_fallback_is_str(self):
        class Weird:
            def __repr__(self):
                return "<weird>"
        assert to_jsonable(Weird()) == "<weird>"

    def test_strict_json_roundtrip(self):
        payload = to_jsonable({"a": float("nan"), "b": (1, 2),
                               "c": np.float32(0.5)})
        assert json.loads(json.dumps(payload, allow_nan=False)) == \
            {"a": None, "b": [1, 2], "c": 0.5}


# ---------------------------------------------------------------------------
# serving-telemetry empty-input semantics (documented in telemetry.py)
# ---------------------------------------------------------------------------

class TestTelemetryEdgeCases:
    def test_percentile_empty_is_nan(self):
        from repro.serve.telemetry import percentile
        assert math.isnan(percentile([], 50))
        assert percentile([1.0, 3.0], 50) == 2.0

    def test_fleet_summary_empty_fleet(self):
        from repro.serve.telemetry import fleet_summary
        s = fleet_summary([])
        assert s["replicas"] == 0
        assert s["requests"] == 0
        assert s["throughput_tok_s"] == 0.0        # count denominator -> 0
        assert math.isnan(s["ttft_steps_p50"])     # no samples -> nan
        assert math.isnan(s["itl_s_p95"])

    def test_summary_zero_finished_requests(self):
        from repro.serve.telemetry import ServeTelemetry
        tel = ServeTelemetry()
        s = tel.summary()
        assert s["requests"] == 0 and s["completed"] == 0
        assert math.isnan(s["ttft_steps_mean"])
        assert math.isnan(s["ttft_steps_p95"])
        assert s["throughput_tok_s"] == 0.0
        assert s["prefix_hit_rate"] == 0.0
        assert s["slot_utilization"] == 0.0
        assert s["queue_depth_max"] == 0
        # submitted but never admitted: still no nan crashes
        tel.on_submit(0, 0, slo="interactive", prompt_tokens=4)
        s = tel.summary()
        assert s["requests"] == 1 and s["completed"] == 0
        assert math.isnan(s["ttft_steps_mean"])

    def test_cancelled_only_traces_keep_counts_not_samples(self):
        from repro.serve.telemetry import ServeTelemetry
        tel = ServeTelemetry()
        tel.on_submit(0, 0)
        tel.on_finish(0, 3, cancelled=True)       # no first token
        tel.on_submit(1, 1)
        tel.on_finish(1, 5, timed_out=True)
        s = tel.summary()
        assert s["cancelled"] == 1 and s["timed_out"] == 1
        assert s["completed"] == 0
        assert math.isnan(s["ttft_steps_mean"])   # no token -> no sample
        # a timed-out request WITH a first token contributes TTFT
        tel.on_submit(2, 2)
        tel.on_token(2, 4)
        tel.on_finish(2, 9, timed_out=True)
        s = tel.summary()
        assert s["ttft_steps_mean"] == 2.0

    def test_request_properties_none_until_defined(self):
        from repro.serve.telemetry import RequestTrace
        t = RequestTrace(rid=0)
        assert t.ttft_steps is None
        assert t.ttft_seconds is None
        assert t.mean_itl_seconds is None

    def test_fleet_summary_json_safe(self):
        from repro.serve.telemetry import ServeTelemetry, fleet_summary
        payload = to_jsonable(fleet_summary([ServeTelemetry()]))
        assert payload["ttft_steps_p50"] is None
        json.dumps(payload, allow_nan=False)      # strict JSON, no raise


# ---------------------------------------------------------------------------
# drift consumers: integrity pipeline + agent cost model
# ---------------------------------------------------------------------------

class TestDriftConsumers:
    def _drifted_report(self):
        d = DriftDetector(min_samples=1)
        d.observe("kernel.gemm", 1.0, 0.5)                 # beats the bound
        d.observe("bytes.model", 1.0, 2.0, unit="bytes",
                  calibrated=True)                          # stale model
        d.observe("healthy.op", 1.0, 1.05)
        return d.report()

    def test_review_drift_labels(self):
        from repro.core.integrity.pipeline import review_drift
        reviews = review_drift(self._drifted_report())
        by_cat = {r.category: r for r in reviews}
        assert by_cat["sustained_below_sol_bound"].label == "sol_ceiling"
        assert by_cat["stale_cost_model"].label == "minor"
        assert len(reviews) == 2                  # healthy op not reviewed
        assert review_drift({}) == []

    def test_cite_drift_report(self):
        from repro.core.agent.costmodel import cite_drift_report
        assert "no drift report" in cite_drift_report(None)
        assert "no drift report" in cite_drift_report({})
        healthy = DriftDetector()
        healthy.observe("op", 1.0, 1.0)
        assert "no sustained drift" in cite_drift_report(healthy.report())
        cite = cite_drift_report(self._drifted_report())
        assert cite.startswith("DRIFT on 2/3 op(s)")
        assert "kernel.gemm below_bound" in cite
        assert "bytes.model above_model" in cite
