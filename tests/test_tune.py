"""Autotuner subsystem: cache roundtrip, shape buckets, SOL pruning, the
measured-tuning runner, and the two-level compile cache."""

import os

import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import tune  # noqa: E402
from repro.core.dsl import compiler  # noqa: E402
from repro.core.tune.cache import TuningCache, TuningRecord  # noqa: E402
from repro.kernels import ops  # noqa: E402


@pytest.fixture
def tune_dir(tmp_path, monkeypatch):
    d = str(tmp_path / "tune")
    monkeypatch.setenv("REPRO_TUNE_DIR", d)
    monkeypatch.delenv("REPRO_TUNE_DISABLE", raising=False)
    return d


def _record(**over):
    base = dict(
        op="gemm", shape_bucket=(64, 64, 64), dtype="fp32",
        backend="pallas", device_kind="testdev",
        best={"tile": [64, 128, 128], "stages": 2},
        trials=[{"config": {"tile": [64, 128, 128], "stages": 2},
                 "median_s": 1e-4}],
    )
    base.update(over)
    return TuningRecord(**base)


# ---------------------------------------------------------------------------
# cache
# ---------------------------------------------------------------------------

class TestCacheRoundtrip:
    def test_write_reload_hit(self, tune_dir):
        cache = TuningCache(tune_dir)
        cache.put(_record())
        # a *fresh* instance (new process analogue) must see the record
        reloaded = TuningCache(tune_dir)
        rec = reloaded.get("gemm", (64, 64, 64), "fp32", device="testdev")
        assert rec is not None
        assert rec.best == {"tile": [64, 128, 128], "stages": 2}
        assert rec.median_for(rec.best) == pytest.approx(1e-4)

    def test_miss_on_different_key(self, tune_dir):
        cache = TuningCache(tune_dir)
        cache.put(_record())
        assert cache.get("gemm", (64, 64, 64), "bf16",
                         device="testdev") is None
        assert cache.get("attention", (64, 64, 64), "fp32",
                         device="testdev") is None
        assert cache.get("gemm", (64, 64, 64), "fp32",
                         device="otherdev") is None

    def test_atomic_file_valid_json(self, tune_dir):
        import json

        cache = TuningCache(tune_dir)
        cache.put(_record())
        cache.put(_record(dtype="bf16"))
        from repro.core.tune.cache import SCHEMA_VERSION

        with open(cache.file) as f:
            payload = json.load(f)
        assert payload["schema"] == SCHEMA_VERSION
        assert len(payload["records"]) == 2
        for rec in payload["records"].values():
            assert rec["schema_version"] == SCHEMA_VERSION

    def test_disable_env(self, tune_dir, monkeypatch):
        cache = TuningCache(tune_dir)
        cache.put(_record())
        monkeypatch.setenv("REPRO_TUNE_DISABLE", "1")
        assert cache.get("gemm", (64, 64, 64), "fp32",
                         device="testdev") is None
        assert tune.lookup("gemm", (64, 64, 64), "fp32") is None


class TestShapeBucket:
    def test_stability_within_band(self):
        # nearby shapes share a bucket -> one tuned config covers the band
        assert tune.shape_bucket((100, 80, 60)) == \
            tune.shape_bucket((97, 70, 50))
        assert tune.shape_bucket((100, 80, 60)) == (128, 128, 64)

    def test_powers_of_two_fixed(self):
        assert tune.shape_bucket((128, 256, 512)) == (128, 256, 512)

    def test_floor(self):
        assert tune.shape_bucket((1, 3)) == (8, 8)

    def test_band_edges_differ(self):
        assert tune.shape_bucket((128,)) != tune.shape_bucket((129,))


# ---------------------------------------------------------------------------
# candidates + SOL pruning
# ---------------------------------------------------------------------------

class TestCandidates:
    def test_default_is_first(self):
        cands = tune.enumerate_candidates("gemm", (256, 256, 512))
        assert cands[0].as_dict() == {"tile": [256, 256, 512], "stages": 2}

    def test_alignment_constraints(self):
        from repro.core.sol.hardware import SUBLANE_MULTIPLE

        for dtype in ("fp32", "bf16"):
            sub = SUBLANE_MULTIPLE[dtype]
            for c in tune.enumerate_candidates("gemm", (256, 256, 512),
                                               dtype=dtype):
                bm, bn, bk = c.as_dict()["tile"]
                assert bm % sub == 0 or (bm, bn, bk) == (256, 256, 512)
                assert bn % 128 == 0 and bk % 128 == 0

    def test_attention_window_gating(self):
        for c in tune.enumerate_candidates("attention", (512, 512, 64),
                                           window=128):
            cfg = c.as_dict()
            assert cfg["block_kv"] <= 128
            assert cfg["block_kv"] % 128 == 0

    def test_ssd_chunks_aligned(self):
        for c in tune.enumerate_candidates("ssd_scan", (256, 64, 64),
                                           dtype="bf16"):
            assert c.as_dict()["chunk"] % 16 == 0


class TestSOLPruning:
    def test_keeps_analytic_best(self):
        shape = (512, 512, 512)
        cands = tune.enumerate_candidates("gemm", shape, dtype="bf16")
        preds = [tune.predict_seconds("gemm", shape, c, dtype="bf16")
                 for c in cands]
        best_idx = min(range(len(cands)), key=lambda i: preds[i])
        kept = tune.prune("gemm", shape, cands, dtype="bf16", top_k=3)
        kept_cfgs = [c.config for c, _ in kept]
        assert cands[best_idx].config in kept_cfgs

    def test_always_keeps_default(self):
        shape = (512, 512, 512)
        cands = tune.enumerate_candidates("gemm", shape, dtype="bf16")
        kept = tune.prune("gemm", shape, cands, dtype="bf16", top_k=2)
        assert cands[0].config in [c.config for c, _ in kept]

    def test_top_k_bounds_measured_set(self):
        shape = (512, 512, 512)
        cands = tune.enumerate_candidates("gemm", shape, dtype="bf16")
        kept = tune.prune("gemm", shape, cands, dtype="bf16", top_k=3)
        assert len(kept) <= 4        # top-3 plus (maybe) the default


# ---------------------------------------------------------------------------
# runner: measured tuning + persistence
# ---------------------------------------------------------------------------

def _gemm_builder(m, n, k):
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)

    def make_fn(cfg):
        tile = tuple(cfg["tile"])
        return lambda: ops.gemm(a, b, tile=tile)

    return make_fn


class TestRunner:
    def test_second_run_zero_trials(self, tune_dir):
        make_fn = _gemm_builder(32, 32, 32)
        r1 = tune.tune_op("gemm", (32, 32, 32), "fp32", make_fn,
                          top_k=2, trials=1)
        assert not r1.from_cache and r1.trials_run > 0
        # fresh cache instance = fresh process; zero measured trials
        r2 = tune.tune_op("gemm", (32, 32, 32), "fp32", make_fn,
                          cache=TuningCache(tune_dir), top_k=2, trials=1)
        assert r2.from_cache and r2.trials_run == 0
        assert r2.record.best == r1.record.best

    def test_best_not_worse_than_default(self, tune_dir):
        make_fn = _gemm_builder(32, 32, 32)
        r = tune.tune_op("gemm", (32, 32, 32), "fp32", make_fn,
                         top_k=2, trials=1, force=True)
        default = {"tile": list(tune.DEFAULT_GEMM_TILE), "stages": 2}
        t_def = r.record.median_for(default)
        t_best = r.record.median_for(r.record.best)
        assert t_def is not None, "default config must always be measured"
        assert t_best <= t_def

    def test_tuned_lookup_feeds_ops(self, tune_dir):
        make_fn = _gemm_builder(32, 32, 32)
        tune.tune_op("gemm", (32, 32, 32), "fp32", make_fn, top_k=2,
                     trials=1)
        tile = tune.tuned_gemm_tile(32, 32, 32, jnp.float32)
        assert tile is not None and len(tile) == 3
        # ops.gemm(tile=None) resolves the same tuned config and still
        # computes the right product
        rng = np.random.default_rng(1)
        a = jnp.asarray(rng.standard_normal((32, 32)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((32, 32)), jnp.float32)
        np.testing.assert_allclose(np.asarray(ops.gemm(a, b)),
                                   np.asarray(a @ b), rtol=1e-4, atol=1e-4)


class TestAgentSeeding:
    def test_seed_hint_consults_cache(self, tune_dir):
        from repro.core.problems import all_problems, problem_ids

        probs = all_problems()
        problem = next(p for p in (probs[pid] for pid in problem_ids())
                       if any(s.kind == "matmul" for s in p.segments))
        seg = next(s for s in problem.segments if s.kind == "matmul")
        d = dict(seg.dims)
        cache = TuningCache(tune_dir)
        cache.put(_record(
            shape_bucket=tune.shape_bucket((d["m"], d["n"], d["k"])),
            device_kind=tune.device_kind()))
        hint = tune.seed_hint_for_problem(problem, dtype="fp32")
        assert hint["tiles"][seg.name] == (64, 128, 128)

    def test_seed_hint_empty_on_cold_cache(self, tune_dir):
        from repro.core.problems import all_problems, problem_ids

        probs = all_problems()
        problem = probs[problem_ids()[0]]
        hint = tune.seed_hint_for_problem(problem, dtype="fp32")
        assert hint == {"tiles": {}, "blocks": {}, "chunks": {}}


# ---------------------------------------------------------------------------
# two-level compile cache
# ---------------------------------------------------------------------------

_DSL = ("gemm().with_dtype(input=bf16, acc=fp32, output=bf16)"
        ".with_tile(m=128, n=128, k=256)")


class TestCompileCache:
    def test_disk_hit_after_memory_clear(self, tmp_path, monkeypatch):
        build = str(tmp_path / "build")
        monkeypatch.delenv("REPRO_COMPILE_CACHE_DIR", raising=False)
        compiler.clear_cache(disk=False)
        k1 = compiler.compile_dsl(_DSL, build_dir=build)
        assert not k1.from_disk_cache
        # clear ONLY the memory layer; the disk layer must serve the hit
        compiler.clear_cache(disk=False)
        k2 = compiler.compile_dsl(_DSL, build_dir=build)
        assert k2.from_disk_cache
        assert k2.source == k1.source
        a = jnp.ones((64, 64), jnp.float32)
        assert k2(a, a).shape == (64, 64)

    def test_clear_cache_clears_disk_layer(self, tmp_path, monkeypatch):
        build = str(tmp_path / "build")
        monkeypatch.setenv("REPRO_COMPILE_CACHE_DIR", build)
        compiler.clear_cache(disk=False)
        compiler.compile_dsl(_DSL)
        assert any(f.startswith("upallas_") for f in os.listdir(build))
        compiler.clear_cache()
        assert not any(f.startswith("upallas_") for f in os.listdir(build))
        k = compiler.compile_dsl(_DSL)
        assert not k.from_disk_cache

    def test_memory_lru_bounded(self, monkeypatch):
        monkeypatch.setenv("REPRO_COMPILE_CACHE_SIZE", "3")
        monkeypatch.delenv("REPRO_COMPILE_CACHE_DIR", raising=False)
        compiler.clear_cache(disk=False)
        for m in (64, 128, 192, 256, 320):
            compiler.compile_dsl(
                f"gemm().with_dtype(input=fp32, acc=fp32, output=fp32)"
                f".with_tile(m={m}, n=128, k=128)")
        assert len(compiler._CACHE) == 3

    def test_corrupt_disk_entry_falls_back(self, tmp_path, monkeypatch):
        build = str(tmp_path / "build")
        monkeypatch.delenv("REPRO_COMPILE_CACHE_DIR", raising=False)
        compiler.clear_cache(disk=False)
        k1 = compiler.compile_dsl(_DSL, build_dir=build)
        # corrupt the cached source; compile must regenerate, not crash
        path = os.path.join(build, f"{k1.namespace}_pallas.py")
        with open(path, "w") as f:
            f.write("this is ( not python")
        compiler.clear_cache(disk=False)
        k2 = compiler.compile_dsl(_DSL, build_dir=build)
        assert not k2.from_disk_cache
        assert k2.source == k1.source
