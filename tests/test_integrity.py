"""Adversarial verdict gate: detectors, composition, the quarantine
ledger, the fault-tolerant measurement protocol, enforcement at the tune /
agent / serve choke points, and integrity-pipeline edge cases."""

import json
import math
import os
import types

import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import tune  # noqa: E402
from repro.core.agent import VARIANTS, run_variant  # noqa: E402
from repro.core.agent.costmodel import cite_gate_verdict  # noqa: E402
from repro.core.agent.runlog import Attempt, RunLog  # noqa: E402
from repro.core.integrity import gate  # noqa: E402
from repro.core.integrity.adversary import (  # noqa: E402
    constant_folded_executable, dead_code_adversary, flaky_fn, hanging_fn,
    slow_fn, timer_cheat_clock, wrong_output_adversary)
from repro.core.integrity.pipeline import (  # noqa: E402
    InflationReport, category_breakdown, inflation, review_drift, review_log)
from repro.core.obs.drift import DriftEvent  # noqa: E402
from repro.core.problems import get_problem  # noqa: E402
from repro.core.sol.hlo_analysis import FoldCheck, detect_folding  # noqa: E402
from repro.core.tune.cache import (  # noqa: E402
    CACHE_FILENAME, SCHEMA_VERSION, TuningCache, TuningRecord)
from repro.core.tune.runner import (  # noqa: E402
    MeasureError, measure_protocol)
from repro.kernels import ops  # noqa: E402
from repro.kernels.ref import gemm_ref  # noqa: E402


@pytest.fixture
def tune_dir(tmp_path, monkeypatch):
    d = str(tmp_path / "tune")
    monkeypatch.setenv("REPRO_TUNE_DIR", d)
    monkeypatch.delenv("REPRO_TUNE_DISABLE", raising=False)
    monkeypatch.delenv("REPRO_INTEGRITY", raising=False)
    return d


def _gemm_case(shape, seed=0):
    m, n, k = shape
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)

    def make_fn(cfg):
        return lambda: ops.gemm(a, b, tile=tuple(cfg["tile"]))

    return make_fn, (lambda: gemm_ref(a, b))


def _report(warmup=1, times=(1e-3, 1e-3, 1e-3), clock_skew=1.0):
    return types.SimpleNamespace(warmup=warmup, times=list(times),
                                 clock_skew=clock_skew)


# ---------------------------------------------------------------------------
# detector units
# ---------------------------------------------------------------------------

class TestOracleCheck:
    def test_exact_match_passes(self):
        x = np.arange(12.0).reshape(3, 4)
        r = gate.check_oracle(x, x.copy())
        assert r.ok and r.reason == ""

    def test_perturbed_fails_with_reason(self):
        x = np.arange(1.0, 13.0).reshape(3, 4)
        r = gate.check_oracle(x * 1.5, x)
        assert not r.ok
        assert r.reason == "oracle_mismatch"
        assert r.evidence["rel_error"] > r.evidence["budget"]

    def test_shape_mismatch_is_infinite_error(self):
        assert gate.rel_error(np.zeros(3), np.zeros(4)) == float("inf")

    def test_nonfinite_output_fails(self):
        x = np.ones(4)
        bad = np.array([1.0, float("nan"), 1.0, 1.0])
        assert not gate.check_oracle(bad, x).ok

    def test_budget_widens_with_dtype(self):
        x = np.ones((4, 4))
        noisy = x * (1.0 + 5e-3)        # past fp32 budget, inside bf16's
        assert not gate.check_oracle(noisy, x, dtype="fp32").ok
        assert gate.check_oracle(noisy, x, dtype="bf16").ok

    def test_quantized_wdtype_reuses_quant_budget(self):
        assert gate.oracle_budget("fp32", "int8") == \
            tune.quant_error_budget("int8")
        assert gate.oracle_budget("fp32", None) == \
            gate.DEFAULT_ORACLE_BUDGETS["fp32"]


class TestSolBoundCheck:
    def test_beating_the_bound_is_impossible(self):
        r = gate.check_sol_bound(0.1, 0.5)
        assert not r.ok and r.reason == "sol_impossible"

    def test_within_tolerance_passes(self):
        assert gate.check_sol_bound(0.45, 0.5).ok     # 10% under: within tol
        assert gate.check_sol_bound(1.5, 0.5).ok

    def test_no_bound_skips(self):
        assert gate.check_sol_bound(0.1, None).ok
        assert gate.check_sol_bound(0.1, 0.0).ok
        assert gate.check_sol_bound(float("nan"), 0.5).ok


class TestHloFoldCheck:
    def test_folded_foldcheck_fails(self):
        fc = FoldCheck(folded=True, reason="flops_collapsed",
                       compiled_flops=0.0, compiled_bytes=0.0,
                       priced_flops=1e9, priced_bytes=0.0, ratio=0.01)
        r = gate.check_hlo_fold(fc, priced_flops=1e9, priced_bytes=0.0)
        assert not r.ok and r.reason == "hlo_folded"

    def test_constant_folded_executable_detected(self):
        compiled, flops, hbm = constant_folded_executable()
        fc = detect_folding(compiled, priced_flops=flops, priced_bytes=hbm)
        assert fc.folded and fc.reason == "flops_collapsed"

    def test_honest_executable_not_folded(self):
        a = jnp.ones((64, 64), jnp.float32)
        compiled = jax.jit(lambda x, y: x @ y).lower(a, a).compile()
        fc = detect_folding(compiled, priced_flops=2.0 * 64 ** 3)
        assert not fc.folded

    def test_no_cost_analysis_is_indeterminate_not_folded(self):
        fc = detect_folding(object(), priced_flops=1e9)
        assert not fc.folded
        assert fc.reason == "no_cost_analysis"


class TestTimingProtocolCheck:
    def test_clean_report_passes(self):
        assert gate.check_timing_protocol(_report()).ok

    def test_collapsed_clock_skew_is_timer_cheat(self):
        r = gate.check_timing_protocol(_report(clock_skew=0.01))
        assert not r.ok and r.reason == "timer_cheat"

    def test_dispatch_count_cross_check(self):
        r = gate.check_timing_protocol(_report(), expected_dispatches=3,
                                       observed_dispatches=5)
        assert not r.ok and r.reason == "dispatch_mismatch"
        assert gate.check_timing_protocol(_report(), expected_dispatches=3,
                                          observed_dispatches=3).ok

    def test_missing_warmup_or_trials_is_protocol_violation(self):
        assert gate.check_timing_protocol(_report(warmup=0)).reason == \
            "protocol_violation"
        assert gate.check_timing_protocol(_report(times=())).reason == \
            "protocol_violation"

    def test_timer_cheat_outranks_protocol(self):
        r = gate.check_timing_protocol(_report(warmup=0, clock_skew=0.01))
        assert r.reason == "timer_cheat"


# ---------------------------------------------------------------------------
# verdict composition + escape hatch
# ---------------------------------------------------------------------------

class TestVerdictComposition:
    def test_honest_measurement_accepts(self, tune_dir):
        x = np.ones((4, 4))
        v = gate.gate_measurement("t.op", measured_s=1.0, t_sol_s=0.5,
                                  output=x, expected=x.copy(),
                                  report=_report())
        assert v.accepted and v.reason_codes == []

    def test_quarantine_reason_wins(self, tune_dir):
        x = np.ones((4, 4))
        v = gate.gate_measurement("t.op", measured_s=1.0, output=x * 2,
                                  expected=x, report=_report(warmup=0))
        assert v.quarantined
        assert "oracle_mismatch" in v.reason_codes
        assert v.evidence["oracle"]["rel_error"] == pytest.approx(1.0)

    def test_protocol_only_rejects(self, tune_dir):
        v = gate.gate_measurement("t.op", measured_s=1.0,
                                  report=_report(warmup=0))
        assert v.decision == gate.REJECT
        assert v.reason_codes == ["protocol_violation"]

    def test_escape_hatch_accepts_everything(self, tune_dir, monkeypatch):
        monkeypatch.setenv("REPRO_INTEGRITY", "off")
        x = np.ones((4, 4))
        v = gate.gate_measurement("t.op", measured_s=1e-12, t_sol_s=1.0,
                                  output=x * 5, expected=x)
        assert v.accepted and v.evidence.get("disabled") is True

    def test_verdict_as_dict_roundtrips_json(self, tune_dir):
        v = gate.gate_measurement("t.op", config={"tile": [8, 8, 8]},
                                  measured_s=0.1, t_sol_s=1.0)
        assert json.loads(json.dumps(v.as_dict()))["decision"] == "quarantine"

    def test_verdict_from_review_mapping(self):
        mk = lambda label: types.SimpleNamespace(  # noqa: E731
            label=label, category="", reasons=[])
        assert gate.verdict_from_review(mk("no_issues")).accepted
        assert gate.verdict_from_review(mk("minor")).accepted
        v = gate.verdict_from_review(mk("sol_ceiling"))
        assert v.quarantined and v.reason_codes == ["sol_impossible"]
        v = gate.verdict_from_review(mk("original_gaming"))
        assert v.quarantined and v.reason_codes == ["oracle_mismatch"]
        assert gate.verdict_from_review(mk("failed")).decision == gate.REJECT

    def test_verdict_from_drift(self):
        below = DriftEvent(op="gemm", direction="below_bound", mean_ratio=0.5,
                           n=8, unit="s", predicted=1.0, measured=0.5)
        v = gate.verdict_from_drift(below)
        assert v is not None and v.quarantined
        assert v.reason_codes == ["sol_impossible"]
        above = DriftEvent(op="gemm", direction="above_model", mean_ratio=2.0,
                           n=8, unit="s", predicted=1.0, measured=2.0)
        assert gate.verdict_from_drift(above) is None


# ---------------------------------------------------------------------------
# quarantine ledger
# ---------------------------------------------------------------------------

class TestQuarantineLedger:
    def _verdict(self):
        return gate.Verdict(decision=gate.QUARANTINE,
                            reason_codes=["oracle_mismatch"], op="t")

    def test_quarantine_blocks_and_persists(self, tune_dir):
        led = gate.QuarantineLedger(tune_dir)
        cfg = {"tile": [64, 64, 64]}
        led.quarantine("k1", cfg, self._verdict())
        assert led.is_quarantined("k1", cfg)
        assert led.is_quarantined("k1")               # any-config form
        assert not led.is_quarantined("k1", {"tile": [8, 8, 8]})
        assert not led.is_quarantined("k2", cfg)
        # a fresh instance (new-process analogue) still blocks
        led2 = gate.QuarantineLedger(tune_dir)
        assert led2.is_quarantined("k1", cfg)
        assert led2.entries_for("k1")[0]["reasons"] == ["oracle_mismatch"]

    def test_release_is_the_audited_path_back(self, tune_dir):
        led = gate.QuarantineLedger(tune_dir)
        cfg = {"tile": [64, 64, 64]}
        led.quarantine("k1", cfg, self._verdict())
        assert led.release("k1", cfg) == 1
        assert not led.is_quarantined("k1", cfg)
        assert gate.QuarantineLedger(tune_dir).is_quarantined("k1") is False

    def test_escape_hatch_stops_blocking_keeps_entries(self, tune_dir,
                                                       monkeypatch):
        led = gate.QuarantineLedger(tune_dir)
        led.quarantine("k1", {"a": 1}, self._verdict())
        monkeypatch.setenv("REPRO_INTEGRITY", "off")
        assert not led.is_quarantined("k1", {"a": 1})
        assert len(led) == 1                           # entries kept

    def test_corrupt_ledger_renamed_aside(self, tune_dir):
        os.makedirs(tune_dir, exist_ok=True)
        path = os.path.join(tune_dir, gate.LEDGER_FILENAME)
        with open(path, "w") as f:
            f.write("{not json")
        led = gate.QuarantineLedger(tune_dir)
        assert len(led) == 0
        aside = [p for p in os.listdir(tune_dir)
                 if p.startswith(gate.LEDGER_FILENAME + ".corrupt-")]
        assert len(aside) == 1
        # and the ledger works normally afterwards
        led.quarantine("k1", {"a": 1}, self._verdict())
        assert led.is_quarantined("k1")

    def test_global_ledger_follows_tune_dir(self, tune_dir):
        assert gate.global_ledger().dir == tune_dir


# ---------------------------------------------------------------------------
# fault-tolerant measurement protocol
# ---------------------------------------------------------------------------

class TestMeasureProtocol:
    def test_clean_measurement(self):
        rep = measure_protocol(slow_fn(1e-4), warmup=1, trials=3)
        # MAD rejection may drop tight-jitter trials; survivors remain
        assert 1 <= len(rep.times) <= 3 + 3      # trials + extras budget
        assert math.isfinite(rep.median_s) and rep.median_s > 0
        assert rep.retries == 0 and rep.timeouts == 0

    def test_transient_flake_absorbed_by_retry(self):
        rep = measure_protocol(flaky_fn(failures=1), warmup=1, trials=2)
        assert rep.retries >= 1
        assert len(rep.times) == 2

    def test_persistent_failure_raises_after_budget(self):
        with pytest.raises(MeasureError, match="retries"):
            measure_protocol(flaky_fn(failures=99), warmup=0, trials=1,
                             max_retries=1, backoff_s=0.001)

    def test_hang_cut_off_by_timeout(self):
        stop = [False]
        try:
            with pytest.raises(MeasureError, match="timeout"):
                measure_protocol(hanging_fn(stop=stop), warmup=0, trials=1,
                                 timeout_s=0.15, max_retries=0)
        finally:
            stop[0] = True

    def test_mad_outlier_rejection(self):
        # scripted claimed-clock: trial 5 is a 100x outlier, replacements
        # are clean — the median must not be poisoned by the spike
        dts = [1e-3, 1.1e-3, 0.9e-3, 1e-3, 0.1] + [1e-3] * 8
        script = [x for dt in dts for x in (0.0, dt)]
        it = iter(script)

        def clock():
            return next(it, 0.0)

        rep = measure_protocol(lambda: None, warmup=0, trials=5, clock=clock)
        assert rep.outliers_rejected >= 1
        assert rep.median_s == pytest.approx(1e-3, rel=0.5)

    def test_timer_cheat_collapses_clock_skew(self):
        rep = measure_protocol(slow_fn(0.002), warmup=1, trials=2,
                               clock=timer_cheat_clock(0.01))
        assert rep.clock_skew < gate.CLOCK_SKEW_FLOOR

    def test_result_captured_for_oracle(self):
        rep = measure_protocol(lambda: 42, warmup=0, trials=1)
        assert rep.result == 42


# ---------------------------------------------------------------------------
# choke point 1: the tuner
# ---------------------------------------------------------------------------

class TestTuneEnforcement:
    def test_honest_tune_unaffected(self, tune_dir):
        make_fn, ref = _gemm_case((64, 64, 64))
        res = tune.tune_op("gemm", (64, 64, 64), "fp32", make_fn, top_k=2,
                           trials=1, force=True, ref=ref)
        assert res.quarantined == []
        assert tune.lookup("gemm", (64, 64, 64), "fp32") == res.record.best

    def test_adversary_quarantined_never_cached(self, tune_dir):
        adv = dead_code_adversary()
        with pytest.raises(RuntimeError, match="quarantined"):
            tune.tune_op("gemm", (64, 64, 64), "fp32", adv.make_fn, top_k=2,
                         trials=1, force=True, ref=adv.ref)
        assert tune.global_cache().get("gemm", (64, 64, 64), "fp32") is None
        key = gate.ledger_key("gemm", (64, 64, 64), "fp32")
        entries = gate.global_ledger().entries_for(key)
        assert entries
        assert all("oracle_mismatch" in e["reasons"] for e in entries)

    def test_ledger_blocks_readmission_before_measuring(self, tune_dir):
        adv = wrong_output_adversary()
        with pytest.raises(RuntimeError):
            tune.tune_op("gemm", (64, 64, 64), "fp32", adv.make_fn, top_k=1,
                         trials=1, force=True, ref=adv.ref)
        # second run: the same configs are ledger-blocked pre-measure, so
        # even an honest fn never re-measures the quarantined config set
        with pytest.raises(RuntimeError) as ei:
            tune.tune_op("gemm", (64, 64, 64), "fp32", adv.make_fn, top_k=1,
                         trials=1, force=True, ref=adv.ref)
        assert "quarantined" in str(ei.value)

    def test_candidate_failure_records_error_type(self, tune_dir):
        make_fn, ref = _gemm_case((64, 64, 64))
        cands = tune.enumerate_candidates("gemm", (64, 64, 64), dtype="fp32")
        bad_cfg = cands[-1].as_dict()

        def flaky_make_fn(cfg):
            if cfg == bad_cfg:
                raise ValueError("illegal on this backend")
            return make_fn(cfg)

        res = tune.tune_op("gemm", (64, 64, 64), "fp32", flaky_make_fn,
                           top_k=len(cands), trials=1, force=True)
        assert any(f["error_type"] == "ValueError" for f in res.failures)
        assert res.record.best != bad_cfg

    def test_escape_hatch_skips_gating(self, tune_dir, monkeypatch):
        monkeypatch.setenv("REPRO_INTEGRITY", "off")
        adv = dead_code_adversary()
        res = tune.tune_op("gemm", (64, 64, 64), "fp32", adv.make_fn,
                           top_k=1, trials=1, force=True, ref=adv.ref)
        assert res.quarantined == []


# ---------------------------------------------------------------------------
# choke point 2: serve-side tuned-config resolution
# ---------------------------------------------------------------------------

class TestServeChokePoint:
    def _metric(self):
        from repro.core.obs.metrics import default_registry

        c = default_registry().counter(
            "repro_integrity_quarantined",
            "measured verdicts quarantined/rejected by the integrity gate",
            labels=("source", "decision"))
        return c.value(source="tune_lookup", decision="quarantine")

    def test_quarantined_record_never_resolves(self, tune_dir):
        make_fn, ref = _gemm_case((64, 64, 64))
        res = tune.tune_op("gemm", (64, 64, 64), "fp32", make_fn, top_k=2,
                           trials=1, force=True, ref=ref)
        rec = res.record
        before = self._metric()
        gate.global_ledger().quarantine(
            rec.key, rec.best,
            gate.Verdict(decision=gate.QUARANTINE,
                         reason_codes=["sol_impossible"]))
        # the serve engine (and kernels.ops, and agent trial-0 seeding)
        # resolve through tune.lookup: quarantined -> safe default + metric
        assert tune.lookup("gemm", (64, 64, 64), "fp32") is None
        assert self._metric() == before + 1
        gate.global_ledger().release(rec.key)
        assert tune.lookup("gemm", (64, 64, 64), "fp32") == rec.best

    def test_drift_gate_wiring(self, tune_dir):
        from repro.core.obs.drift import DriftDetector

        det = DriftDetector(window=4, min_samples=4)
        gate.install_drift_gate(det)
        n0 = len(gate.drift_verdicts())
        for _ in range(4):                 # sustained beats-physics window
            det.observe("t.below", predicted=1.0, measured=0.1,
                        calibrated=False)
        verdicts = gate.drift_verdicts()
        assert len(verdicts) > n0
        assert verdicts[-1].op == "t.below"
        assert verdicts[-1].reason_codes == ["sol_impossible"]


# ---------------------------------------------------------------------------
# choke point 3: agent scoring
# ---------------------------------------------------------------------------

class TestAgentScoring:
    def _log(self):
        return RunLog(problem_id="p", variant="v", capability="c", seed=0,
                      t_ref=1.0, t_sol=0.1, t_sol_ceiling=0.08)

    def test_scored_speedup_zeroes_gamed_attempts(self):
        a = Attempt(index=0, phase="implement", description="", tokens=1,
                    ok=True, runtime_s=0.5, speedup=2.0)
        assert a.scored_speedup == 2.0
        a.label = "original_gaming"
        assert a.scored_speedup == 0.0
        a.label = "no_issues"
        a.verdict = {"decision": "quarantine"}
        assert a.scored_speedup == 0.0
        a.verdict = {"decision": "accept"}
        assert a.scored_speedup == 2.0
        a.ok = False
        assert a.scored_speedup == 0.0

    def test_gated_best_speedup_reviews_on_the_fly(self):
        log = self._log()
        log.record(Attempt(index=0, phase="implement", description="",
                           tokens=1, ok=True, runtime_s=0.5, speedup=2.0))
        # beats the bf16 SOL ceiling: physically impossible, scores zero
        log.record(Attempt(index=1, phase="implement", description="",
                           tokens=1, ok=True, runtime_s=0.01, speedup=100.0))
        assert log.gated_best_speedup() == 2.0
        assert log.attempts[1].label == "sol_ceiling"

    def test_agent_attempts_gated_eagerly(self, tune_dir):
        p = get_problem("L2/76")
        logs = run_variant(VARIANTS["orch_dsl"], [p], capability="mini",
                           seed=0)
        assert logs[0].attempts
        for a in logs[0].attempts:
            assert a.label != ""               # labeled at record time
            assert a.verdict is not None
            assert "citation" in a.verdict
        # gamed/failed attempts carry non-accept verdicts with citations
        bad = [a for a in logs[0].attempts
               if a.label not in ("no_issues", "minor")]
        for a in bad:
            assert a.verdict["decision"] in ("reject", "quarantine")
            assert a.scored_speedup == 0.0

    def test_citation_text(self):
        assert "no gate verdict" in cite_gate_verdict(None)
        assert "accepted" in cite_gate_verdict({"decision": "accept",
                                                "reason_codes": []})
        q = cite_gate_verdict({"decision": "quarantine",
                               "reason_codes": ["sol_impossible"],
                               "evidence": {"label": "sol_ceiling"}})
        assert "QUARANTINE" in q and "scores zero" in q


# ---------------------------------------------------------------------------
# tuning-cache hardening (satellite)
# ---------------------------------------------------------------------------

class TestCacheHardening:
    def test_corrupt_cache_renamed_aside_not_fatal(self, tune_dir):
        os.makedirs(tune_dir, exist_ok=True)
        path = os.path.join(tune_dir, CACHE_FILENAME)
        with open(path, "w") as f:
            f.write("xx{ not json !!")
        cache = TuningCache(tune_dir)
        assert len(cache) == 0                 # empty, not an exception
        aside = [p for p in os.listdir(tune_dir)
                 if p.startswith(CACHE_FILENAME + ".corrupt-")]
        assert len(aside) == 1
        with open(os.path.join(tune_dir, aside[0])) as f:
            assert f.read().startswith("xx{")  # evidence preserved

    def test_schema_version_mismatch_rejected(self, tune_dir):
        rec = TuningRecord(op="gemm", shape_bucket=(64, 64, 64),
                           dtype="fp32", backend="pallas",
                           device_kind="testdev", best={"tile": [64, 64, 64]})
        d = dict(rec.__dict__)
        d["schema_version"] = SCHEMA_VERSION + 1
        with pytest.raises(ValueError, match="schema"):
            TuningRecord.from_dict(d)

    def test_stale_record_skipped_on_load(self, tune_dir):
        cache = TuningCache(tune_dir)
        cache.put(TuningRecord(op="gemm", shape_bucket=(64, 64, 64),
                               dtype="fp32", backend="pallas",
                               device_kind="testdev",
                               best={"tile": [64, 64, 64]}))
        with open(cache.file) as f:
            payload = json.load(f)
        assert payload["schema"] == SCHEMA_VERSION
        key = next(iter(payload["records"]))
        payload["records"][key]["schema_version"] = SCHEMA_VERSION + 1
        with open(cache.file, "w") as f:
            json.dump(payload, f)
        reloaded = TuningCache(tune_dir)
        assert reloaded.get("gemm", (64, 64, 64), "fp32",
                            device="testdev") is None


# ---------------------------------------------------------------------------
# integrity-pipeline edges (satellite)
# ---------------------------------------------------------------------------

class TestPipelineEdges:
    def test_review_drift_empty_report(self):
        assert review_drift({}) == []
        assert review_drift({"op": {"drifting": False}}) == []

    def test_review_drift_nan_window_does_not_crash(self):
        report = {"op": {"drifting": True, "direction": "below_bound",
                         "mean_ratio": float("nan"), "window_n": 0,
                         "unit": "s"}}
        reviews = review_drift(report)
        assert len(reviews) == 1
        assert reviews[0].label == "sol_ceiling"

    def test_review_drift_above_model_is_minor(self):
        report = {"op": {"drifting": True, "direction": "above_model",
                         "mean_ratio": 2.0, "window_n": 8, "unit": "s"}}
        reviews = review_drift(report)
        assert reviews[0].label == "minor"
        assert reviews[0].category == "stale_cost_model"

    def _gamed_log(self):
        log = RunLog(problem_id="p", variant="v", capability="c", seed=0,
                     t_ref=1.0, t_sol=0.1, t_sol_ceiling=0.08)
        log.attempts = [
            Attempt(index=0, phase="i", description="", tokens=1, ok=True,
                    runtime_s=0.2, speedup=5.0, flags=["constant_output"]),
            Attempt(index=1, phase="i", description="", tokens=1, ok=False,
                    runtime_s=float("inf"), speedup=0.0),
        ]
        return log

    def test_inflation_with_zero_accepted_attempts(self):
        rep = inflation([self._gamed_log()])
        assert math.isfinite(rep.max_inflation)
        assert rep.allow_gaming >= rep.filtered_geomean
        # degenerate report: no accepted mass at all
        assert InflationReport(filtered_geomean=0.0, allow_pytorch_only=0.0,
                               allow_gaming=0.0,
                               unfiltered=3.0).max_inflation == 0.0

    def test_category_breakdown_mixed(self):
        log = RunLog(problem_id="p", variant="v", capability="c", seed=0,
                     t_ref=1.0, t_sol=0.1, t_sol_ceiling=0.08)
        mk = lambda i, **kw: Attempt(  # noqa: E731
            index=i, phase="i", description="", tokens=1, ok=True,
            runtime_s=0.2, speedup=5.0, **kw)
        log.attempts = [
            mk(0, flags=["constant_output"]),
            mk(1, flags=["skip:epilogue"]),
            mk(2, flags=["input_exploit"]),
            mk(3, flags=["passthrough"]),
            mk(4, flags=["reduced_precision"]),
            mk(5),                                     # no_issues: no category
        ]
        cats = category_breakdown([log])
        assert cats["constant_or_hardcoded_output"] == 1
        assert cats["skipped_computation_step"] == 1
        assert cats["benchmark_input_exploitation"] == 1
        assert cats["library_composition"] == 1
        assert cats["minor_math_approximation"] == 1
        assert sum(cats.values()) == 5
        counts = review_log(log)
        assert counts.get("no_issues") == 1
